//! Run-loop benchmark: the event loop's hot paths — delay calls and
//! whole per-scheme runs — measured on the cached-kinematics fast path
//! vs the kept pre-cache reference (`SimEnv::set_reference_path` +
//! `testkit::ReferenceSurrogate`), per scenario preset. Every speedup
//! is equality-gated: the reference and fast runs must produce
//! bit-identical delays / accuracy curves / transfer counts before a
//! number is reported.
//!
//! Emits `BENCH_runloop.json` (delay-calls/sec fast vs reference, run
//! wall-time per scheme, before/after speedups, and the PR-9 multi-lane
//! run time + speedup per scheme — equality-gated against the
//! single-lane run) so the perf trajectory of the run loop is tracked
//! across PRs.
//!
//! Run: `cargo bench --offline --bench bench_runloop`
//!      (`-- --presets paper-40,sparse-iot` selects presets; default is
//!      paper-40 + the 1584-satellite starlink-phase1 stress world;
//!      `-- --lanes N` sets the multi-lane run's lane count, default 4)

use asyncfleo::bench::{bench, print_header, BenchConfig};
use asyncfleo::config::ExperimentConfig;
use asyncfleo::coordinator::{Geometry, RunResult, SimEnv};
use asyncfleo::experiments::scenarios::SCENARIO_SCHEMES;
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::scenario::ScenarioRegistry;
use asyncfleo::testkit::{assert_runs_identical, ReferenceSurrogate};
use asyncfleo::train::SurrogateBackend;
use std::io::Write;
use std::time::Instant;

/// Delay probes per timed micro-bench iteration.
const DELAY_CALLS: usize = 20_000;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let presets: Vec<String> = match args.iter().position(|a| a == "--presets") {
        Some(i) => {
            let value = args
                .get(i + 1)
                .unwrap_or_else(|| panic!("--presets needs a comma-separated preset list"));
            value.split(',').map(str::to_string).collect()
        }
        None => vec!["paper-40".to_string(), "starlink-phase1".to_string()],
    };
    let lanes: usize = match args.iter().position(|a| a == "--lanes") {
        Some(i) => args
            .get(i + 1)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--lanes needs a positive integer")),
        None => 4,
    };

    let reg = ScenarioRegistry::builtin();
    let mut rows: Vec<String> = Vec::new();
    for name in &presets {
        let sc = reg
            .get(name)
            .unwrap_or_else(|| panic!("unknown preset {name}; known: {:?}", reg.names()));
        let cfg = bench_cfg(sc.cfg.clone());
        // prewarm the shared geometry so run timings measure the event
        // loop, not the contact-plan build
        Geometry::shared(&cfg);

        let (calls_fast, calls_ref) = delay_benches(name, &cfg);

        print_header(&format!("{name}: whole runs, fast vs reference (surrogate)"));
        let mut scheme_rows: Vec<String> = Vec::new();
        for &(label, scheme) in SCENARIO_SCHEMES {
            let mut c = cfg.clone();
            c.fl.scheme = scheme;
            let (fast_r, fast_s, fast_phases) = timed_run(&c, false);
            let (ref_r, ref_s, _) = timed_run(&c, true);
            assert_runs_identical(&fast_r, &ref_r, &format!("{name}/{label}"));
            // multi-lane run, equality-gated against the single-lane
            // fast run before its speedup is reported
            let (lane_r, lane_s) = timed_run_lanes(&c, lanes);
            assert_runs_identical(&lane_r, &fast_r, &format!("{name}/{label}/lanes{lanes}"));
            let speedup = ref_s / fast_s.max(1e-9);
            let lanes_speedup = fast_s / lane_s.max(1e-9);
            println!(
                "{name}/{label}: fast {fast_s:.3} s, reference {ref_s:.3} s  ({speedup:.2}x, {} epochs, {} transfers); lanes={lanes} {lane_s:.3} s ({lanes_speedup:.2}x vs fast)",
                fast_r.epochs,
                fast_r.transfers
            );
            let phases_json: Vec<String> = fast_phases
                .iter()
                .map(|(n, s, cnt)| {
                    format!("{{\"name\": \"{n}\", \"secs\": {s:.6}, \"count\": {cnt}}}")
                })
                .collect();
            scheme_rows.push(format!(
                "        {{\"scheme\": \"{}\", \"fast_s\": {fast_s:.6}, \"reference_s\": {ref_s:.6}, \"speedup\": {speedup:.4}, \"lanes\": {lanes}, \"lanes_s\": {lane_s:.6}, \"lanes_speedup\": {lanes_speedup:.4}, \"epochs\": {}, \"transfers\": {}, \"phases\": [{}]}}",
                scheme.name(),
                fast_r.epochs,
                fast_r.transfers,
                phases_json.join(", "),
            ));
        }

        rows.push(format!(
            "    {{\"name\": \"{name}\", \"sats\": {}, \"horizon_s\": {:.1}, \"delay_calls_per_sec_fast\": {calls_fast:.1}, \"delay_calls_per_sec_reference\": {calls_ref:.1}, \"delay_speedup\": {:.4}, \"schemes\": [\n{}\n      ]}}",
            cfg.n_sats(),
            cfg.fl.horizon_s,
            calls_fast / calls_ref.max(1e-9),
            scheme_rows.join(",\n"),
        ));
    }

    // process-wide substrate phases (geometry build, contact scan,
    // analytic pass-map memoization) accumulated across every preset
    let substrate: Vec<String> = asyncfleo::obs::global_phases()
        .into_iter()
        .map(|(n, s, c)| format!("    {{\"name\": \"{n}\", \"secs\": {s:.6}, \"count\": {c}}}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"runloop\",\n  \"delay_calls_per_iter\": {DELAY_CALLS},\n  \"substrate_phases\": [\n{}\n  ],\n  \"presets\": [\n{}\n  ]\n}}\n",
        substrate.join(",\n"),
        rows.join(",\n")
    );
    let mut f = std::fs::File::create("BENCH_runloop.json").expect("create BENCH_runloop.json");
    f.write_all(json.as_bytes()).expect("write BENCH_runloop.json");
    println!("\nwrote BENCH_runloop.json");
}

/// Trim a preset to bench size: runs stay in seconds while each still
/// drives thousands of delay calls and full aggregation epochs.
fn bench_cfg(mut cfg: ExperimentConfig) -> ExperimentConfig {
    if cfg.n_sats() >= 5000 {
        // the 10k+ worlds are a smoke: one short horizon exercises the
        // plan build, broadcasts and aggregation without dominating CI
        cfg.fl.horizon_s = cfg.fl.horizon_s.min(6.0 * 3600.0);
        cfg.fl.max_epochs = cfg.fl.max_epochs.min(2);
    } else if cfg.n_sats() >= 1000 {
        cfg.fl.horizon_s = cfg.fl.horizon_s.min(12.0 * 3600.0);
        cfg.fl.max_epochs = cfg.fl.max_epochs.min(6);
    } else {
        cfg.fl.horizon_s = cfg.fl.horizon_s.min(24.0 * 3600.0);
        cfg.fl.max_epochs = cfg.fl.max_epochs.min(12);
    }
    cfg
}

/// The deterministic probe sequence both paths replay: site, ISL and
/// IHL delays across the horizon. Returns the folded sum (the equality
/// gate compares the two paths' sums bitwise — any diverging delay
/// would have to cancel exactly to slip through, and the per-call test
/// suite already pins call-by-call equality).
fn delay_probe(env: &mut SimEnv, n_sites: usize, n_sats: usize, horizon: f64) -> f64 {
    let mut acc = 0.0f64;
    for k in 0..DELAY_CALLS {
        let t = (k as f64 * 37.5) % horizon;
        match k % 3 {
            0 => acc += env.site_link_delay(k % n_sites, k % n_sats, t),
            1 => acc += env.isl_hop_delay(k % n_sats, (k + 1) % n_sats, t),
            _ => acc += env.ihl_hop_delay(k % n_sites, (k + 1) % n_sites, t),
        }
    }
    acc
}

/// Delay-call throughput, fast vs reference, equality-gated.
/// Returns (calls/sec fast, calls/sec reference).
fn delay_benches(name: &str, cfg: &ExperimentConfig) -> (f64, f64) {
    print_header(&format!("{name}: delay calls, fast vs reference ({DELAY_CALLS} per iter)"));
    let n_sites = cfg.placement.sites().len();
    let n_sats = cfg.n_sats();
    let horizon = cfg.fl.horizon_s;

    let mut b_fast = SurrogateBackend::for_config(cfg);
    let mut env_fast = SimEnv::new(cfg, &mut b_fast);
    let mut b_ref = SurrogateBackend::for_config(cfg);
    let mut env_ref = SimEnv::new(cfg, &mut b_ref);
    env_ref.set_reference_path(true);

    // identity gate before timing anything
    let sum_fast = delay_probe(&mut env_fast, n_sites, n_sats, horizon);
    let sum_ref = delay_probe(&mut env_ref, n_sites, n_sats, horizon);
    assert_eq!(
        sum_fast.to_bits(),
        sum_ref.to_bits(),
        "{name}: fast delay path diverged from the reference formulas"
    );

    let bcfg = BenchConfig { warmup_iters: 2, sample_iters: 10, max_seconds: 120.0 };
    let r_fast = bench(&format!("{name}: fast path"), &bcfg, || {
        delay_probe(&mut env_fast, n_sites, n_sats, horizon)
    });
    println!("{}", r_fast.report());
    let r_ref = bench(&format!("{name}: reference path"), &bcfg, || {
        delay_probe(&mut env_ref, n_sites, n_sats, horizon)
    });
    println!("{}", r_ref.report());

    let calls_fast = DELAY_CALLS as f64 / r_fast.stats.mean.max(1e-12);
    let calls_ref = DELAY_CALLS as f64 / r_ref.stats.mean.max(1e-12);
    println!(
        "{name}: {:.2} Mcalls/s fast vs {:.2} Mcalls/s reference ({:.2}x)",
        calls_fast / 1e6,
        calls_ref / 1e6,
        calls_fast / calls_ref.max(1e-9)
    );
    (calls_fast, calls_ref)
}

/// One whole strategy run, timed. `reference` routes delays through the
/// pre-cache formulas and model compute through the allocating
/// `ReferenceSurrogate` plumbing. The fast run carries metrics-only
/// observation so its per-scheme phase split (event loop vs
/// aggregation) lands in `BENCH_runloop.json` — the timing therefore
/// *includes* the observation overhead, which doubles as a live gate
/// that it stays near zero (results are bit-identical either way;
/// `assert_runs_identical` above pins that against the unobserved
/// reference run).
/// One whole strategy run on the fast path with the PR-9 multi-lane
/// event core (same metrics-only observation as the single-lane fast
/// run, so the two wall times compare like for like).
fn timed_run_lanes(cfg: &ExperimentConfig, lanes: usize) -> (RunResult, f64) {
    let mut strategy = make_strategy(cfg.fl.scheme);
    let mut b = SurrogateBackend::for_config(cfg);
    let mut env = SimEnv::new(cfg, &mut b);
    env.set_lanes(lanes);
    env.enable_obs(asyncfleo::obs::RunObs::metrics_only());
    let t0 = Instant::now();
    let r = strategy.run(&mut env);
    (r, t0.elapsed().as_secs_f64())
}

fn timed_run(
    cfg: &ExperimentConfig,
    reference: bool,
) -> (RunResult, f64, Vec<(&'static str, f64, u64)>) {
    let mut strategy = make_strategy(cfg.fl.scheme);
    if reference {
        let mut b = ReferenceSurrogate(SurrogateBackend::for_config(cfg));
        let mut env = SimEnv::new(cfg, &mut b);
        env.set_reference_path(true);
        let t0 = Instant::now();
        let r = strategy.run(&mut env);
        (r, t0.elapsed().as_secs_f64(), Vec::new())
    } else {
        let mut b = SurrogateBackend::for_config(cfg);
        let mut env = SimEnv::new(cfg, &mut b);
        env.enable_obs(asyncfleo::obs::RunObs::metrics_only());
        let t0 = Instant::now();
        let r = strategy.run(&mut env);
        let wall = t0.elapsed().as_secs_f64();
        let phases = env
            .take_obs()
            .map(|o| o.phases.entries().collect())
            .unwrap_or_default();
        (r, wall, phases)
    }
}
