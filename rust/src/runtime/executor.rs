//! PJRT load-compile-execute wrapper around the `xla` crate.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Outputs are 1-tuples (jax lowered with `return_tuple=True`), so we
//! decompose and hand back plain `Vec<f32>` buffers.

use super::manifest::{ArtifactEntry, DType, Manifest, TensorSpec};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Typed host-side input for one artifact argument.
#[derive(Clone, Debug)]
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Input<'_> {
    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Input::F32(_) => DType::F32,
            Input::I32(_) => DType::I32,
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
    /// Wall-clock execute() time accumulator (perf accounting).
    pub exec_seconds: std::cell::Cell<f64>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Executable {
    /// Execute with shape/dtype-checked inputs; returns one `Vec<f32>`
    /// per output (scalars come back as length-1 vectors).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<Vec<f32>>> {
        let specs = &self.entry.inputs;
        if inputs.len() != specs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                specs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (inp, spec)) in inputs.iter().zip(specs).enumerate() {
            if inp.len() != spec.elements() {
                bail!(
                    "{} input {i}: expected {} elements, got {}",
                    self.entry.name,
                    spec.elements(),
                    inp.len()
                );
            }
            if inp.dtype() != spec.dtype {
                bail!("{} input {i}: dtype mismatch", self.entry.name);
            }
            literals.push(make_literal(inp, spec)?);
        }
        let t0 = std::time::Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let outer = result
            .first()
            .and_then(|r| r.first())
            .context("empty execution result")?
            .to_literal_sync()?;
        self.exec_seconds.set(self.exec_seconds.get() + t0.elapsed().as_secs_f64());
        self.exec_count.set(self.exec_count.get() + 1);
        // jax lowers with return_tuple=True: outputs arrive as a tuple.
        let parts = outer.to_tuple()?;
        if parts.len() != self.entry.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.entry.name,
                self.entry.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for part in parts {
            out.push(part.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Mean seconds per execute() so far (perf accounting).
    pub fn mean_exec_seconds(&self) -> f64 {
        let n = self.exec_count.get();
        if n == 0 {
            0.0
        } else {
            self.exec_seconds.get() / n as f64
        }
    }
}

fn make_literal(inp: &Input, spec: &TensorSpec) -> Result<xla::Literal> {
    let dims: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
    let lit = match inp {
        Input::F32(v) => {
            if spec.dims.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::vec1(v)
        }
        Input::I32(v) => {
            if spec.dims.is_empty() {
                return Ok(xla::Literal::scalar(v[0]));
            }
            xla::Literal::vec1(v)
        }
    };
    Ok(lit.reshape(&dims)?)
}

/// The runtime: a PJRT CPU client plus a cache of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<Executable>>>,
}

impl Runtime {
    /// Create against an artifact directory (must contain manifest.txt).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir).map_err(anyhow::Error::msg)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, dir, cache: Default::default() })
    }

    /// Default artifact directory: `$ASYNCFLEO_ARTIFACTS` or `artifacts/`
    /// relative to the crate root.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ASYNCFLEO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    /// Load + compile an artifact (cached).
    pub fn compile(&self, name: &str) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.artifact(name).map_err(anyhow::Error::msg)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let executable = std::rc::Rc::new(Executable {
            entry,
            exe,
            exec_seconds: std::cell::Cell::new(0.0),
            exec_count: std::cell::Cell::new(0),
        });
        self.cache.borrow_mut().insert(name.to_string(), executable.clone());
        Ok(executable)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of distinct artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    // Executable/Runtime behaviour against real artifacts is covered by
    // rust/tests/runtime_e2e.rs (needs `make artifacts`). Here we test
    // the pure pieces.
    use super::*;

    #[test]
    fn input_len_dtype() {
        let f = [1.0f32, 2.0];
        let i = [3i32];
        assert_eq!(Input::F32(&f).len(), 2);
        assert_eq!(Input::I32(&i).len(), 1);
        assert_eq!(Input::F32(&f).dtype(), DType::F32);
        assert_eq!(Input::I32(&i).dtype(), DType::I32);
    }

    #[test]
    fn default_dir_points_at_crate() {
        let d = Runtime::default_dir();
        assert!(d.ends_with("artifacts") || std::env::var_os("ASYNCFLEO_ARTIFACTS").is_some());
    }
}
