//! Cross-module integration tests on the surrogate backend: full
//! strategy runs over the real geometry/topology/DES substrate (fast,
//! no PJRT), checking the paper's qualitative results end to end.

use asyncfleo::config::{ExperimentConfig, PsPlacement, SchemeKind};
use asyncfleo::coordinator::{RunResult, SimEnv};
use asyncfleo::fl::asyncfleo::AsyncFleo;
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::train::SurrogateBackend;

fn run_scheme(
    scheme: SchemeKind,
    placement: PsPlacement,
    iid: bool,
    horizon_h: f64,
) -> RunResult {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.fl.scheme = scheme;
    cfg.placement = placement;
    cfg.fl.horizon_s = horizon_h * 3600.0;
    cfg.fl.max_epochs = 40;
    let mut backend = SurrogateBackend::paper_split(5, 8, iid, 100);
    let mut env = SimEnv::new(&cfg, &mut backend);
    make_strategy(scheme).run(&mut env)
}

// ---------------------------------------------------------------------
// Table II shape: orderings the paper reports must hold on the
// simulated testbed too.
// ---------------------------------------------------------------------

#[test]
fn asyncfleo_converges_much_faster_than_fedhap() {
    // The paper's headline: same accuracy band, ~6x faster than the
    // synchronous FedHAP. On the surrogate we verify the speed ordering
    // with a stopping-rule-independent metric (time to fixed accuracy);
    // the accuracy-band comparison is the PJRT table2 experiment's job.
    let ours = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 72.0);
    let fedhap = run_scheme(SchemeKind::FedHap, PsPlacement::HapRolla, false, 72.0);
    let t_ours = ours.time_to_accuracy(0.70).expect("asyncfleo reaches 70%");
    let t_hap = fedhap.time_to_accuracy(0.70).expect("fedhap reaches 70%");
    assert!(
        t_ours < t_hap,
        "AsyncFLEO to 70% in {} h should beat FedHAP {} h",
        t_ours / 3600.0,
        t_hap / 3600.0
    );
}

#[test]
fn fedisl_arbitrary_gs_slower_than_asyncfleo_gs() {
    let fedisl = run_scheme(SchemeKind::FedIsl, PsPlacement::GsRolla, false, 72.0);
    let ours = run_scheme(SchemeKind::AsyncFleo, PsPlacement::GsRolla, false, 72.0);
    let t_ours = ours.time_to_accuracy(0.65).expect("asyncfleo reaches 65%");
    let t_isl = fedisl.time_to_accuracy(0.65).unwrap_or(f64::INFINITY);
    assert!(
        t_ours < t_isl,
        "asyncfleo to 65% in {} h vs fedisl {} h",
        t_ours / 3600.0,
        t_isl / 3600.0
    );
}

#[test]
fn fedisl_ideal_np_is_competitive() {
    let ideal = run_scheme(SchemeKind::FedIslIdeal, PsPlacement::GsNorthPole, false, 24.0);
    assert!(ideal.converged.is_some(), "NP FedISL should converge within 24 h");
    let (t, acc) = ideal.converged.unwrap();
    assert!(t < 12.0 * 3600.0, "NP convergence {} h", t / 3600.0);
    assert!(acc > 0.6);
}

#[test]
fn asyncfleo_hap_beats_asyncfleo_gs() {
    let hap = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 48.0);
    let gs = run_scheme(SchemeKind::AsyncFleo, PsPlacement::GsRolla, false, 48.0);
    // HAP's better visibility -> no slower convergence (paper: 5h vs 6h)
    assert!(
        hap.convergence_hours() <= gs.convergence_hours() + 1.0,
        "hap {} vs gs {}",
        hap.convergence_hours(),
        gs.convergence_hours()
    );
}

#[test]
fn fedspace_converges_no_faster_than_asyncfleo() {
    // On the knowledge surrogate FedSpace's *accuracy* weakness (full-
    // weight stale/biased averages) is invisible — that gap shows in
    // the real-training table2 experiment. What the surrogate does
    // capture is cadence: FedSpace's scheduled 2 h aggregation cannot
    // converge earlier than AsyncFLEO's quorum-triggered epochs.
    let fedspace = run_scheme(SchemeKind::FedSpace, PsPlacement::GsRolla, false, 48.0);
    let ours = run_scheme(SchemeKind::AsyncFleo, PsPlacement::GsRolla, false, 48.0);
    let t_ours = ours.time_to_accuracy(0.6).expect("asyncfleo reaches 60%");
    let t_fs = fedspace.time_to_accuracy(0.6).unwrap_or(f64::INFINITY);
    assert!(
        t_ours <= t_fs + 1800.0,
        "asyncfleo to 60% in {} h vs fedspace {} h",
        t_ours / 3600.0,
        t_fs / 3600.0
    );
}

#[test]
fn fedsat_updates_regular_at_np_irregular_elsewhere() {
    // The NP "ideal setup" gives *regular* visits: every satellite
    // updates; with an arbitrary GS the update counts skew (some
    // satellites barely participate). Compare per-run update totals
    // and the first-update latency.
    let np = run_scheme(SchemeKind::FedSat, PsPlacement::GsNorthPole, false, 24.0);
    let arbitrary = run_scheme(SchemeKind::FedSat, PsPlacement::GsRolla, false, 24.0);
    assert!(np.epochs >= arbitrary.epochs, "np {} vs gs {}", np.epochs, arbitrary.epochs);
    assert!(np.final_accuracy >= arbitrary.final_accuracy - 0.03);
    // NP's first recorded evaluation happens early (regular visits)
    let first_np = np.curve.points.get(1).map(|p| p.time_s).unwrap_or(f64::INFINITY);
    assert!(first_np < 6.0 * 3600.0, "first NP eval at {} h", first_np / 3600.0);
}

// ---------------------------------------------------------------------
// Fig. 7/8 shape on the surrogate
// ---------------------------------------------------------------------

#[test]
fn iid_beats_noniid_modestly() {
    let iid = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, true, 48.0);
    let non = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 48.0);
    assert!(iid.final_accuracy >= non.final_accuracy - 0.02);
    assert!(
        non.final_accuracy > iid.final_accuracy - 0.25,
        "non-IID must still learn (iid {} vs non {})",
        iid.final_accuracy,
        non.final_accuracy
    );
}

#[test]
fn two_haps_speed_up_convergence() {
    let one = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 48.0);
    let two = run_scheme(SchemeKind::AsyncFleo, PsPlacement::TwoHaps, false, 48.0);
    let t1 = one.time_to_accuracy(0.70).expect("one-HAP reaches 70%");
    let t2 = two.time_to_accuracy(0.70).expect("two-HAP reaches 70%");
    assert!(
        t2 <= t1 + 1800.0,
        "two-HAP to 70% in {} h vs one-HAP {} h",
        t2 / 3600.0,
        t1 / 3600.0
    );
}

// ---------------------------------------------------------------------
// Ablations (DESIGN.md A1–A3)
// ---------------------------------------------------------------------

fn run_asyncfleo_variant(strat: AsyncFleo, horizon_h: f64) -> RunResult {
    let mut cfg = ExperimentConfig::paper_defaults();
    cfg.placement = PsPlacement::HapRolla;
    cfg.fl.horizon_s = horizon_h * 3600.0;
    cfg.fl.max_epochs = 40;
    let mut backend = SurrogateBackend::paper_split(5, 8, false, 100);
    let mut env = SimEnv::new(&cfg, &mut backend);
    let mut strat = strat;
    strat.run(&mut env)
}

#[test]
fn ablation_staleness_discount_does_not_hurt() {
    let on = run_asyncfleo_variant(AsyncFleo::default(), 48.0);
    let off = run_asyncfleo_variant(
        AsyncFleo { disable_staleness_discount: true, ..Default::default() },
        48.0,
    );
    // discounting protects against stale bias: never worse by much
    assert!(
        on.final_accuracy >= off.final_accuracy - 0.05,
        "discount on {} vs off {}",
        on.final_accuracy,
        off.final_accuracy
    );
}

#[test]
fn ablation_quorum_affects_epoch_cadence() {
    let small = run_asyncfleo_variant(
        AsyncFleo { quorum_frac: 0.1, ..Default::default() },
        24.0,
    );
    let large = run_asyncfleo_variant(
        AsyncFleo { quorum_frac: 0.8, timeout_s: 7200.0, ..Default::default() },
        24.0,
    );
    // cadence: the k-th global epoch happens no later with the smaller
    // quorum (early stopping may end either run sooner, so compare the
    // common prefix of the curves, not the totals)
    let k = (small.curve.points.len().min(large.curve.points.len())).saturating_sub(1);
    assert!(k >= 1, "both runs must produce at least one epoch");
    assert!(
        small.curve.points[k].time_s <= large.curve.points[k].time_s + 1.0,
        "epoch {k}: small-quorum at {} h vs large-quorum at {} h",
        small.curve.points[k].time_s / 3600.0,
        large.curve.points[k].time_s / 3600.0
    );
}

// ---------------------------------------------------------------------
// Determinism: the whole pipeline regenerates bit-identical results
// ---------------------------------------------------------------------

#[test]
fn runs_are_deterministic() {
    let a = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 24.0);
    let b = run_scheme(SchemeKind::AsyncFleo, PsPlacement::HapRolla, false, 24.0);
    assert_eq!(a.epochs, b.epochs);
    assert_eq!(a.curve.points.len(), b.curve.points.len());
    for (x, y) in a.curve.points.iter().zip(&b.curve.points) {
        assert_eq!(x.time_s, y.time_s);
        assert_eq!(x.accuracy, y.accuracy);
    }
}
