"""L1 Pallas kernel: staleness-discounted model aggregation (Eq. 14).

    w^{beta+1}[d] = coeffs[0] * w^beta[d] + sum_n coeffs[n] * w_n[d]

which we express as a single matvec over an extended model slab
models_ext[N+1, D] whose row 0 is the previous global model. The Rust
coordinator computes `coeffs` from the grouping + staleness metadata
(Eq. 13) and calls this compiled artifact on its aggregation hot path —
this is the parameter-server (sink-HAP) compute of the paper.

TPU mapping: the parameter axis D streams through VMEM in TILE_D-wide
slabs while the (small, N+1 ≤ 41) model axis stays resident; one grid
step touches (N+1)·TILE_D + TILE_D floats ≈ 41·2048·4 B ≈ 336 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_D = 2048


def _agg_kernel(m_ref, c_ref, o_ref):
    # [N+1, TD] slab · [N+1] coeffs -> [TD]
    o_ref[...] = jnp.einsum(
        "n,nd->d", c_ref[...], m_ref[...],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_d", "interpret"))
def aggregate(models_ext, coeffs, tile_d=DEFAULT_TILE_D, interpret=True):
    """models_ext: [N+1, D], coeffs: [N+1] -> [D] weighted sum."""
    n1, d = models_ext.shape
    assert coeffs.shape == (n1,)
    td = min(tile_d, d)
    dp = (d + td - 1) // td * td
    mp = jnp.pad(models_ext, ((0, 0), (0, dp - d)))
    out = pl.pallas_call(
        _agg_kernel,
        out_shape=jax.ShapeDtypeStruct((dp,), models_ext.dtype),
        grid=(dp // td,),
        in_specs=[
            pl.BlockSpec((n1, td), lambda i: (0, i)),
            pl.BlockSpec((n1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((td,), lambda i: (i,)),
        interpret=interpret,
    )(mp, coeffs)
    return out[:d]


def vmem_bytes(n1, tile_d=DEFAULT_TILE_D, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (perf model)."""
    return dtype_bytes * (n1 * tile_d + n1 + tile_d)
