"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts per model variant m in {mlp,cnn} x {digits,cifar}:
    init_<m>.hlo.txt    (seed)                      -> params
    train_<m>.hlo.txt   (params, xs, ys, lr)        -> (params', mean_loss)
    eval_<m>.hlo.txt    (params, x, y)              -> (correct, loss_sum)
    agg_<m>.hlo.txt     (models_ext[N+1,D], coeffs) -> params        (Eq. 14)
    dist_<m>.hlo.txt    (models[N,D], ref)          -> divergences   (IV-C1)

plus `manifest.txt`, the machine-readable registry the Rust runtime
parses (shapes, dtypes, tuple arity, training geometry).

Run via `make artifacts`:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Training geometry (paper Table I scaled — see DESIGN.md §5): J local
# SGD steps of batch b per dispatch; eval streams in chunks of EVAL_B.
LOCAL_STEPS = 10
BATCH = 32
EVAL_B = 256
# Aggregation slab: previous global model + up to N_SATS local models.
N_SATS = 40

VARIANTS = [(k, d) for k in ("mlp", "cnn") for d in ("digits", "cifar")]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return f"{dtype}[{','.join(str(s) for s in shape)}]"


def _lower(fn, args):
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_all(out_dir):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    manifest.append(f"config local_steps={LOCAL_STEPS} batch={BATCH} "
                    f"eval_batch={EVAL_B} n_sats={N_SATS}")

    f32 = jnp.float32
    for kind, dataset in VARIANTS:
        name = f"{kind}_{dataset}"
        ds = model.DATASETS[dataset]
        feat = ds["h"] * ds["w"] * ds["c"]
        k = ds["classes"]
        dim = model.param_dim(kind, dataset)
        s = LOCAL_STEPS * BATCH

        jobs = {
            f"init_{name}": (
                model.make_init_fn(kind, dataset),
                [jax.ShapeDtypeStruct((), jnp.int32)],
                [_spec((), "i32")],
                [_spec((dim,))],
            ),
            f"train_{name}": (
                model.make_train_fn(kind, dataset, LOCAL_STEPS, BATCH),
                [
                    jax.ShapeDtypeStruct((dim,), f32),
                    jax.ShapeDtypeStruct((s, feat), f32),
                    jax.ShapeDtypeStruct((s, k), f32),
                    jax.ShapeDtypeStruct((), f32),
                ],
                [_spec((dim,)), _spec((s, feat)), _spec((s, k)), _spec(())],
                [_spec((dim,)), _spec(())],
            ),
            f"eval_{name}": (
                model.make_eval_fn(kind, dataset),
                [
                    jax.ShapeDtypeStruct((dim,), f32),
                    jax.ShapeDtypeStruct((EVAL_B, feat), f32),
                    jax.ShapeDtypeStruct((EVAL_B, k), f32),
                ],
                [_spec((dim,)), _spec((EVAL_B, feat)), _spec((EVAL_B, k))],
                [_spec(()), _spec(())],
            ),
            f"agg_{name}": (
                model.make_agg_fn(N_SATS + 1, dim),
                [
                    jax.ShapeDtypeStruct((N_SATS + 1, dim), f32),
                    jax.ShapeDtypeStruct((N_SATS + 1,), f32),
                ],
                [_spec((N_SATS + 1, dim)), _spec((N_SATS + 1,))],
                [_spec((dim,))],
            ),
            f"dist_{name}": (
                model.make_dist_fn(N_SATS, dim),
                [
                    jax.ShapeDtypeStruct((N_SATS, dim), f32),
                    jax.ShapeDtypeStruct((dim,), f32),
                ],
                [_spec((N_SATS, dim)), _spec((dim,))],
                [_spec((N_SATS,))],
            ),
        }
        manifest.append(f"model {name} dim={dim} feat={feat} classes={k}")
        for art_name, (fn, args, in_specs, out_specs) in jobs.items():
            path = os.path.join(out_dir, f"{art_name}.hlo.txt")
            text = _lower(fn, args)
            with open(path, "w") as f:
                f.write(text)
            manifest.append(
                f"artifact {art_name} file={art_name}.hlo.txt "
                f"in={';'.join(in_specs)} out={';'.join(out_specs)}"
            )
            print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} entries)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
