//! Walker constellation builder (paper Fig. 1, Sec. V-A), generalized
//! to multi-shell constellations for the scenario subsystem.
//!
//! A Walker-delta constellation `i:T/P/F` spreads `P` orbital planes
//! evenly over 360 degrees of RAAN, with `T/P` satellites equally
//! spaced in each plane and an inter-plane phasing factor `F`. A Walker
//! *star* (polar constellations like OneWeb/Iridium) spreads the planes
//! over 180 degrees instead, so ascending and descending passes
//! interleave. A constellation is a list of [`ShellSpec`]s; each shell
//! contributes its own planes and satellites, with globally unique,
//! dense satellite ids (shell 0 first, then shell 1, ...). The paper's
//! single 5×8 shell is the one-element special case.

use super::elements::OrbitalElements;
use super::propagation::PlaneBasis;
use crate::util::Vec3;

/// Which Walker pattern a shell follows.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WalkerPattern {
    /// RAAN spread over 360° (the paper's pattern).
    Delta,
    /// RAAN spread over 180° (polar "star" constellations).
    Star,
}

impl WalkerPattern {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "delta" => Some(WalkerPattern::Delta),
            "star" => Some(WalkerPattern::Star),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WalkerPattern::Delta => "delta",
            WalkerPattern::Star => "star",
        }
    }

    /// RAAN span the shell's planes are spread over.
    fn raan_span_rad(&self) -> f64 {
        match self {
            WalkerPattern::Delta => 2.0 * std::f64::consts::PI,
            WalkerPattern::Star => std::f64::consts::PI,
        }
    }
}

/// One shell of a (possibly multi-shell) constellation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShellSpec {
    pub pattern: WalkerPattern,
    pub n_orbits: usize,
    pub sats_per_orbit: usize,
    pub altitude_km: f64,
    pub inclination_deg: f64,
    /// Walker F factor (relative phase shift between adjacent planes,
    /// in units of 360/T degrees).
    pub phasing: usize,
}

impl ShellSpec {
    /// A delta shell (the common case).
    pub fn delta(
        n_orbits: usize,
        sats_per_orbit: usize,
        altitude_km: f64,
        inclination_deg: f64,
        phasing: usize,
    ) -> Self {
        ShellSpec {
            pattern: WalkerPattern::Delta,
            n_orbits,
            sats_per_orbit,
            altitude_km,
            inclination_deg,
            phasing,
        }
    }

    /// A star shell (planes over 180° of RAAN).
    pub fn star(
        n_orbits: usize,
        sats_per_orbit: usize,
        altitude_km: f64,
        inclination_deg: f64,
        phasing: usize,
    ) -> Self {
        ShellSpec {
            pattern: WalkerPattern::Star,
            ..Self::delta(n_orbits, sats_per_orbit, altitude_km, inclination_deg, phasing)
        }
    }

    pub fn n_sats(&self) -> usize {
        self.n_orbits * self.sats_per_orbit
    }

    /// Compact human-readable form, e.g. `12x20@550km/53°`.
    pub fn summary(&self) -> String {
        format!(
            "{}x{}@{}km/{}°{}",
            self.n_orbits,
            self.sats_per_orbit,
            self.altitude_km,
            self.inclination_deg,
            if self.pattern == WalkerPattern::Star { "*" } else { "" }
        )
    }
}

/// The satellite→plane mapping of a uniform single-shell constellation
/// (`n_orbits` planes of `sats_per_orbit` each) — the legacy
/// "divide by plane size" rule, kept in one place so the partition,
/// surrogate and fault layers can't drift apart. Multi-shell callers
/// use `WalkerConstellation::plane_of` /
/// `ConstellationConfig::plane_of` instead.
pub fn uniform_plane_of(n_orbits: usize, sats_per_orbit: usize) -> Vec<usize> {
    (0..n_orbits * sats_per_orbit).map(|s| s / sats_per_orbit.max(1)).collect()
}

/// A satellite's identity + orbital elements. IDs follow the paper's
/// `(orbit#, sat#)` convention (Fig. 3), extended with the shell index;
/// `orbit` is the *global* plane index across all shells.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Satellite {
    /// Global index in [0, total sats).
    pub id: usize,
    /// Which shell this satellite belongs to.
    pub shell: usize,
    /// Global orbital-plane index in [0, total planes).
    pub orbit: usize,
    /// In-plane index in [0, plane length).
    pub slot: usize,
    pub elements: OrbitalElements,
}

/// Contiguous id span of one orbital plane.
#[derive(Clone, Copy, Debug, PartialEq)]
struct PlaneSpan {
    start: usize,
    len: usize,
}

/// A full (possibly multi-shell) Walker constellation.
#[derive(Clone, Debug)]
pub struct WalkerConstellation {
    pub satellites: Vec<Satellite>,
    /// The shells this constellation was built from.
    pub shells: Vec<ShellSpec>,
    /// Global plane table: contiguous id span per plane.
    planes: Vec<PlaneSpan>,
    /// Cached per-satellite plane bases: the canonical (and fast)
    /// position formula — all time-independent trigonometry hoisted to
    /// construction, so [`Self::position`] is one `cos`/`sin` pair plus
    /// a handful of plain multiplies and adds per call (deliberately
    /// not `mul_add`: contraction would break bit-identity with the
    /// original rotation chain).
    propagators: Vec<PlaneBasis>,
    /// Total number of orbital planes across all shells.
    pub n_orbits: usize,
    /// Satellites per plane of the *first* shell (uniform for
    /// single-shell constellations; use [`Self::plane_len`] for the
    /// general per-plane count).
    pub sats_per_orbit: usize,
}

impl WalkerConstellation {
    /// Build a single delta shell: `P = n_orbits` planes x
    /// `n = sats_per_orbit` satellites (the pre-multi-shell API).
    pub fn new(
        n_orbits: usize,
        sats_per_orbit: usize,
        altitude_km: f64,
        inclination_deg: f64,
        phasing: usize,
    ) -> Self {
        Self::from_shells(&[ShellSpec::delta(
            n_orbits,
            sats_per_orbit,
            altitude_km,
            inclination_deg,
            phasing,
        )])
    }

    /// Build a multi-shell constellation. Satellite ids are dense and
    /// globally unique; shell `k`'s ids follow shell `k-1`'s
    /// ([`Self::shell_id_range`]), and each shell's planes are appended
    /// to the global plane table in order.
    pub fn from_shells(shells: &[ShellSpec]) -> Self {
        assert!(!shells.is_empty(), "constellation needs at least one shell");
        let tau = 2.0 * std::f64::consts::PI;
        let total: usize = shells.iter().map(ShellSpec::n_sats).sum();
        let mut satellites = Vec::with_capacity(total);
        let mut planes = Vec::new();
        for (shell_idx, sh) in shells.iter().enumerate() {
            assert!(
                sh.n_orbits > 0 && sh.sats_per_orbit > 0,
                "shell {shell_idx} must have at least one satellite"
            );
            let shell_total = sh.n_sats();
            let span = sh.pattern.raan_span_rad();
            for o in 0..sh.n_orbits {
                let raan = span * o as f64 / sh.n_orbits as f64;
                let plane = planes.len();
                planes.push(PlaneSpan { start: satellites.len(), len: sh.sats_per_orbit });
                for s in 0..sh.sats_per_orbit {
                    let phase = tau * s as f64 / sh.sats_per_orbit as f64
                        + tau * sh.phasing as f64 * o as f64 / shell_total as f64;
                    satellites.push(Satellite {
                        id: satellites.len(),
                        shell: shell_idx,
                        orbit: plane,
                        slot: s,
                        elements: OrbitalElements {
                            altitude_km: sh.altitude_km,
                            inclination_rad: sh.inclination_deg.to_radians(),
                            raan_rad: raan,
                            phase_rad: phase,
                        },
                    });
                }
            }
        }
        let n_orbits = planes.len();
        let sats_per_orbit = shells[0].sats_per_orbit;
        let propagators = satellites.iter().map(|s| PlaneBasis::new(&s.elements)).collect();
        WalkerConstellation {
            satellites,
            shells: shells.to_vec(),
            planes,
            propagators,
            n_orbits,
            sats_per_orbit,
        }
    }

    /// The paper's evaluation constellation: 40 satellites over 5 orbits
    /// at 2000 km, inclination 80 degrees (Sec. V-A).
    pub fn paper() -> Self {
        WalkerConstellation::new(5, 8, 2000.0, 80.0, 1)
    }

    pub fn len(&self) -> usize {
        self.satellites.len()
    }

    pub fn is_empty(&self) -> bool {
        self.satellites.is_empty()
    }

    pub fn n_shells(&self) -> usize {
        self.shells.len()
    }

    /// Satellites in one plane (planes differ across shells).
    pub fn plane_len(&self, orbit: usize) -> usize {
        self.planes[orbit].len
    }

    /// Global plane index of every satellite (the mapping the faults
    /// and data-partition layers shard by).
    pub fn plane_of(&self) -> Vec<usize> {
        self.satellites.iter().map(|s| s.orbit).collect()
    }

    /// The contiguous global-id range of one shell.
    pub fn shell_id_range(&self, shell: usize) -> std::ops::Range<usize> {
        let start: usize = self.shells[..shell].iter().map(ShellSpec::n_sats).sum();
        start..start + self.shells[shell].n_sats()
    }

    /// Shell index of satellite `id`. Every satellite in a shell shares
    /// altitude and inclination, so per-(shell, site) results — like the
    /// analytic pass maps in `coordinator::analytic` — are computed once
    /// and shared across the whole shell.
    pub fn shell_of(&self, id: usize) -> usize {
        self.satellites[id].shell
    }

    /// Position of satellite `id` at time `t` (ECI, km), via the
    /// cached plane basis (bit-identical to
    /// [`super::propagation::satellite_position_eci`]).
    pub fn position(&self, id: usize, t: f64) -> Vec3 {
        self.propagators[id].position_at(t)
    }

    /// The cached plane-basis propagator of satellite `id` (what
    /// [`Self::position`] evaluates; the contact scanner holds these
    /// directly in its hot loop).
    pub fn propagator(&self, id: usize) -> &PlaneBasis {
        &self.propagators[id]
    }

    /// Intra-orbit ring neighbours of a satellite: the two adjacent
    /// slots in the same plane (paper Sec. IV-A: ISLs only within an
    /// orbit, because inter-orbit relative velocity makes links
    /// unstable / Doppler-dominated).
    pub fn ring_neighbors(&self, id: usize) -> (usize, usize) {
        let sat = &self.satellites[id];
        let span = self.planes[sat.orbit];
        let prev = span.start + (sat.slot + span.len - 1) % span.len;
        let next = span.start + (sat.slot + 1) % span.len;
        (prev, next)
    }

    /// All satellite IDs in one orbital plane (global plane index).
    /// Plane ids are dense and contiguous, so the members are a plain
    /// range — allocation-free to produce and iterate (the run loop's
    /// uplink/relay paths call this per event).
    pub fn orbit_members(&self, orbit: usize) -> std::ops::Range<usize> {
        let span = self.planes[orbit];
        span.start..span.start + span.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_constellation_counts() {
        let c = WalkerConstellation::paper();
        assert_eq!(c.len(), 40);
        assert_eq!(c.n_orbits, 5);
        assert_eq!(c.sats_per_orbit, 8);
        assert_eq!(c.n_shells(), 1);
    }

    #[test]
    fn ids_are_dense_and_ordered() {
        let c = WalkerConstellation::new(3, 4, 800.0, 60.0, 1);
        for (i, s) in c.satellites.iter().enumerate() {
            assert_eq!(s.id, i);
            assert_eq!(s.orbit, i / 4);
            assert_eq!(s.slot, i % 4);
            assert_eq!(s.shell, 0);
        }
    }

    #[test]
    fn raan_evenly_spread() {
        let c = WalkerConstellation::new(5, 8, 2000.0, 80.0, 1);
        let expect = 2.0 * std::f64::consts::PI / 5.0;
        for o in 1..5 {
            let d = c.satellites[o * 8].elements.raan_rad - c.satellites[(o - 1) * 8].elements.raan_rad;
            assert!((d - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn star_pattern_halves_raan_span() {
        let c = WalkerConstellation::from_shells(&[ShellSpec::star(4, 3, 1200.0, 87.9, 1)]);
        let expect = std::f64::consts::PI / 4.0;
        for o in 1..4 {
            let d = c.satellites[o * 3].elements.raan_rad - c.satellites[(o - 1) * 3].elements.raan_rad;
            assert!((d - expect).abs() < 1e-12, "star planes over 180°");
        }
    }

    #[test]
    fn in_plane_spacing_uniform() {
        let c = WalkerConstellation::paper();
        let tau = 2.0 * std::f64::consts::PI;
        for s in 1..8 {
            let d = c.satellites[s].elements.phase_rad - c.satellites[s - 1].elements.phase_rad;
            assert!((d - tau / 8.0).abs() < 1e-12);
        }
    }

    #[test]
    fn equal_spacing_preserved_over_time() {
        // Satellites in the same plane keep constant angular separation.
        let c = WalkerConstellation::paper();
        let t = 5000.0;
        let p0 = c.position(0, t);
        let p1 = c.position(1, t);
        let expect = 2.0 * std::f64::consts::PI / 8.0;
        assert!((p0.angle_to(p1) - expect).abs() < 1e-9);
    }

    #[test]
    fn ring_neighbors_wrap() {
        let c = WalkerConstellation::paper();
        assert_eq!(c.ring_neighbors(0), (7, 1));
        assert_eq!(c.ring_neighbors(7), (6, 0));
        assert_eq!(c.ring_neighbors(8), (15, 9)); // first sat of orbit 1
        assert_eq!(c.ring_neighbors(39), (38, 32));
    }

    #[test]
    fn ring_neighbor_relation_is_symmetric() {
        let c = WalkerConstellation::paper();
        for id in 0..c.len() {
            let (p, n) = c.ring_neighbors(id);
            let (_, pn) = c.ring_neighbors(p);
            let (np, _) = c.ring_neighbors(n);
            assert_eq!(pn, id);
            assert_eq!(np, id);
        }
    }

    #[test]
    fn orbit_members_partition_constellation() {
        let c = WalkerConstellation::paper();
        let mut all: Vec<usize> = (0..5).flat_map(|o| c.orbit_members(o)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<_>>());
    }

    fn two_shell() -> WalkerConstellation {
        WalkerConstellation::from_shells(&[
            ShellSpec::delta(2, 3, 550.0, 53.0, 1),
            ShellSpec::delta(3, 4, 1110.0, 53.8, 1),
        ])
    }

    #[test]
    fn multi_shell_ids_disjoint_and_dense() {
        let c = two_shell();
        assert_eq!(c.len(), 6 + 12);
        assert_eq!(c.n_orbits, 5, "2 + 3 planes");
        assert_eq!(c.shell_id_range(0), 0..6);
        assert_eq!(c.shell_id_range(1), 6..18);
        for (i, s) in c.satellites.iter().enumerate() {
            assert_eq!(s.id, i);
            assert_eq!(s.shell, usize::from(i >= 6));
        }
        // altitudes follow the shell
        assert_eq!(c.satellites[0].elements.altitude_km, 550.0);
        assert_eq!(c.satellites[6].elements.altitude_km, 1110.0);
    }

    #[test]
    fn shell_of_follows_id_ranges() {
        let c = two_shell();
        for shell in 0..c.n_shells() {
            for id in c.shell_id_range(shell) {
                assert_eq!(c.shell_of(id), shell);
            }
        }
    }

    #[test]
    fn multi_shell_planes_have_per_shell_lengths() {
        let c = two_shell();
        assert_eq!(c.plane_len(0), 3);
        assert_eq!(c.plane_len(1), 3);
        assert_eq!(c.plane_len(2), 4);
        assert_eq!(c.plane_len(4), 4);
        assert_eq!(c.orbit_members(2), 6..10);
        let plane_of = c.plane_of();
        assert_eq!(plane_of[0], 0);
        assert_eq!(plane_of[5], 1);
        assert_eq!(plane_of[6], 2);
        assert_eq!(plane_of[17], 4);
    }

    #[test]
    fn multi_shell_ring_neighbors_stay_in_shell() {
        let c = two_shell();
        for id in 0..c.len() {
            let (p, n) = c.ring_neighbors(id);
            assert_eq!(c.satellites[p].shell, c.satellites[id].shell);
            assert_eq!(c.satellites[n].shell, c.satellites[id].shell);
            assert_eq!(c.satellites[p].orbit, c.satellites[id].orbit);
            let (_, pn) = c.ring_neighbors(p);
            assert_eq!(pn, id, "symmetry across uneven plane lengths");
        }
        // wrap inside the second shell's first plane (ids 6..10)
        assert_eq!(c.ring_neighbors(6), (9, 7));
        assert_eq!(c.ring_neighbors(9), (8, 6));
    }

    #[test]
    fn multi_shell_members_partition_constellation() {
        let c = two_shell();
        let mut all: Vec<usize> = (0..c.n_orbits).flat_map(|o| c.orbit_members(o)).collect();
        all.sort_unstable();
        assert_eq!(all, (0..c.len()).collect::<Vec<_>>());
    }
}
