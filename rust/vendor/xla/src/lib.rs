//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate links the native XLA/PJRT runtime and is only
//! available on hosts where the rust_pallas toolchain ships it. This
//! stub mirrors the API surface `asyncfleo::runtime` uses so the L3
//! crate always *compiles*; every runtime entry point returns a clear
//! error instead. All experiment drivers accept `--surrogate`, and the
//! tier-1 test suite runs entirely on the surrogate backend, so nothing
//! in CI depends on a live PJRT client. Swap this path dependency for
//! the real `xla` crate (and run `make artifacts`) to execute the AOT
//! JAX/Pallas artifacts.

use std::fmt;

/// Stub error: carries the failing operation's name.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(op: &str) -> Error {
    Error(format!(
        "xla stub: {op} requires the native PJRT runtime (this build vendors \
         the offline stub; use --surrogate, or link the real xla crate)"
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// A compiled executable (stub: never actually constructible).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device-side buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal (stub: holds no data).
pub struct Literal;

impl Literal {
    pub fn scalar<T>(_value: T) -> Literal {
        Literal
    }

    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("PJRT"), "{e}");
    }

    #[test]
    fn literal_shape_ops_are_inert() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
