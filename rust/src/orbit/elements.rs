//! Orbital elements and physical constants (paper Sec. III).

/// Mean Earth radius, km (the paper's R_E).
pub const EARTH_RADIUS_KM: f64 = 6371.0;

/// Standard gravitational parameter GM of the Earth, km^3/s^2.
pub const MU_EARTH: f64 = 398_600.4418;

/// Earth rotation rate, rad/s (sidereal).
pub const EARTH_ROTATION_RAD_S: f64 = 7.292_115_9e-5;

/// Circular-orbit elements for one satellite.
///
/// The paper's constellation is circular Walker-delta, so eccentricity
/// and argument of perigee are fixed at zero and the state is fully
/// described by altitude, inclination, RAAN and initial phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OrbitalElements {
    /// Orbital altitude above the surface, km (paper h_o).
    pub altitude_km: f64,
    /// Inclination, radians.
    pub inclination_rad: f64,
    /// Right ascension of the ascending node, radians.
    pub raan_rad: f64,
    /// Phase (argument of latitude) at t = 0, radians.
    pub phase_rad: f64,
}

impl OrbitalElements {
    /// Semi-major axis = R_E + h_o, km.
    pub fn semi_major_axis_km(&self) -> f64 {
        EARTH_RADIUS_KM + self.altitude_km
    }

    /// Orbital velocity v_o = sqrt(GM / (R_E + h_o)), km/s (paper Sec. III).
    pub fn velocity_km_s(&self) -> f64 {
        (MU_EARTH / self.semi_major_axis_km()).sqrt()
    }

    /// Orbital period T_o = 2*pi*(R_E + h_o) / v_o, seconds (paper Sec. III).
    pub fn period_s(&self) -> f64 {
        2.0 * std::f64::consts::PI * self.semi_major_axis_km() / self.velocity_km_s()
    }

    /// Mean motion n = 2*pi / T_o, rad/s.
    pub fn mean_motion_rad_s(&self) -> f64 {
        2.0 * std::f64::consts::PI / self.period_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_orbit() -> OrbitalElements {
        // Sec. V-A: h_o = 2000 km, inclination 80 deg.
        OrbitalElements {
            altitude_km: 2000.0,
            inclination_rad: 80f64.to_radians(),
            raan_rad: 0.0,
            phase_rad: 0.0,
        }
    }

    #[test]
    fn velocity_near_paper_figure() {
        // Paper Sec. IV-C1 quotes ~25,000 km/h orbital velocity.
        let v_kmh = paper_orbit().velocity_km_s() * 3600.0;
        assert!(
            (23_000.0..27_000.0).contains(&v_kmh),
            "v = {v_kmh} km/h should be near the paper's ~25,000 km/h"
        );
    }

    #[test]
    fn period_about_127_minutes() {
        // T = 2*pi*sqrt(a^3/mu) at a = 8371 km is ~127 min.
        let t_min = paper_orbit().period_s() / 60.0;
        assert!((125.0..130.0).contains(&t_min), "T = {t_min} min");
    }

    #[test]
    fn period_consistent_with_kepler_third_law() {
        let e = paper_orbit();
        let a = e.semi_major_axis_km();
        let kepler = 2.0 * std::f64::consts::PI * (a * a * a / MU_EARTH).sqrt();
        assert!((e.period_s() - kepler).abs() / kepler < 1e-12);
    }

    #[test]
    fn higher_orbit_slower() {
        let lo = OrbitalElements { altitude_km: 500.0, ..paper_orbit() };
        let hi = OrbitalElements { altitude_km: 2000.0, ..paper_orbit() };
        assert!(lo.velocity_km_s() > hi.velocity_km_s());
        assert!(lo.period_s() < hi.period_s());
    }
}
