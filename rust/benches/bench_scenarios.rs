//! Scenario-catalog benchmark: geometry build cost per preset world
//! (the dominating per-scenario cost the shared cache amortizes) and
//! the scheme×scenario comparison grid throughput on the streaming
//! executor, sequential vs `--jobs 4`.
//!
//! Emits `BENCH_scenarios.json` so the perf trajectory of the scenario
//! subsystem is tracked across PRs.
//!
//! Run: `cargo bench --offline --bench bench_scenarios`

use asyncfleo::bench::black_box;
use asyncfleo::coordinator::Geometry;
use asyncfleo::experiments::drivers::ExpOptions;
use asyncfleo::experiments::executor::run_cells;
use asyncfleo::experiments::scenarios::compare_cells;
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use std::io::Write;
use std::time::Instant;

const PAR_JOBS: usize = 4;

fn main() {
    let registry = ScenarioRegistry::builtin();

    // Cold geometry build per preset, on a bench-sized horizon (the
    // scan cost scales linearly with horizon; 12 h ranks the worlds
    // without a multi-minute bench run).
    println!("== per-preset geometry build (12 h horizon) ==");
    let mut geometry_lines = Vec::new();
    for sc in registry.iter() {
        let mut cfg = sc.cfg.clone();
        cfg.fl.horizon_s = 12.0 * 3600.0;
        let t0 = Instant::now();
        black_box(Geometry::build(&cfg));
        let dt = t0.elapsed().as_secs_f64();
        println!("{:<18} {:>5} sats  {:>9.3} s", sc.name, sc.cfg.n_sats(), dt);
        geometry_lines.push(format!(
            "    {{\"name\": \"{}\", \"sats\": {}, \"build_s\": {dt:.6}}}",
            sc.name,
            sc.cfg.n_sats()
        ));
    }

    // Comparison-grid throughput on the two cheapest presets (fast
    // sizes), sequential vs parallel.
    let scenarios: Vec<Scenario> = ["sparse-iot", "paper-40"]
        .iter()
        .map(|n| registry.get(n).expect("preset").clone())
        .collect();
    let opts_seq = ExpOptions { fast: true, surrogate: true, jobs: 1, ..Default::default() };
    let opts_par = ExpOptions { jobs: PAR_JOBS, ..opts_seq.clone() };
    let cells = compare_cells(&scenarios, &opts_seq);
    let n_cells = cells.len();
    for cell in &cells {
        Geometry::shared(&cell.cfg); // warm: measure run time, not build
    }

    let t0 = Instant::now();
    let seq = run_cells(&cells, &opts_seq).expect("sequential grid");
    let sequential_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let par = run_cells(&cells, &opts_par).expect("parallel grid");
    let parallel_s = t0.elapsed().as_secs_f64();

    // determinism sanity: a bench must never report a speedup on wrong
    // results
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.epochs, b.epochs, "parallel grid diverged from sequential");
        assert_eq!(a.transfers, b.transfers, "parallel grid diverged from sequential");
    }

    let speedup = sequential_s / parallel_s.max(1e-9);
    println!("\n== scenario comparison grid ({n_cells} cells, fast surrogate) ==");
    println!(
        "sequential (--jobs 1):    {sequential_s:>9.3} s  ({:.2} cells/s)",
        n_cells as f64 / sequential_s
    );
    println!(
        "parallel   (--jobs {PAR_JOBS}):    {parallel_s:>9.3} s  ({:.2} cells/s)",
        n_cells as f64 / parallel_s
    );
    println!("speedup:                  {speedup:>9.2} x");

    let json = format!(
        "{{\n  \"bench\": \"scenarios\",\n  \"presets\": {},\n  \"grid_cells\": {n_cells},\n  \"jobs\": {PAR_JOBS},\n  \"geometry_builds\": [\n{}\n  ],\n  \"sequential_s\": {sequential_s:.6},\n  \"parallel_s\": {parallel_s:.6},\n  \"speedup\": {speedup:.4}\n}}\n",
        registry.len(),
        geometry_lines.join(",\n"),
    );
    let mut f = std::fs::File::create("BENCH_scenarios.json").expect("create BENCH_scenarios.json");
    f.write_all(json.as_bytes()).expect("write BENCH_scenarios.json");
    println!("wrote BENCH_scenarios.json");
}
