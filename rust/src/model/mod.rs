//! Model state as the L3 coordinator sees it: flat `f32` parameter
//! buffers plus the paper's per-satellite metadata tuple
//! ⟨ID, size, loc, ts, epoch⟩ (Sec. IV-C1).
//!
//! The flat layout is frozen by `python/compile/model.py::layer_shapes`;
//! L3 never interprets the contents — it relays, groups, distances and
//! aggregates whole buffers (the latter two through the compiled L1
//! kernels on the hot path, with pure-Rust fallbacks here for tests and
//! for simulator-only runs).

pub mod metadata;
pub mod params;

pub use metadata::ModelMetadata;
pub use params::ModelParams;
