//! `asyncfleo` — launcher CLI for the AsyncFLEO paper reproduction.
//!
//! ```text
//! asyncfleo exp <name>|all [--out DIR] [--fast] [--surrogate] [--seed N] [--jobs N]
//! asyncfleo run [--config FILE] [--scheme S] [--placement P] ...
//! asyncfleo resilience [--out DIR] [--fast] [--surrogate] [--seed N] [--jobs N]
//!                      [--scenarios NAME[,NAME..]]
//! asyncfleo scenario [--list | --dump NAME | --preset NAME[,NAME..] | --all | --config FILE]
//! asyncfleo trace [--preset NAME] [--scheme S] [--seed N] [--out FILE] [--lanes N]
//! asyncfleo report [TRACE.jsonl]
//! asyncfleo info
//! ```

use asyncfleo::cli::Args;
use asyncfleo::config::{ExperimentConfig, ModelKind, PsPlacement, SchemeKind};
use asyncfleo::experiments::drivers::{print_info, run_one, ExpOptions};
use asyncfleo::experiments::run_experiment;
use asyncfleo::fl::{make_strategy, Strategy};
use asyncfleo::scenario::{Scenario, ScenarioRegistry};
use asyncfleo::util::fmt_hm;

const USAGE: &str = "\
asyncfleo — AsyncFLEO paper reproduction (Rust + JAX + Pallas)

USAGE:
  asyncfleo exp <name>|all [--out DIR] [--fast] [--surrogate] [--seed N] [--jobs N]
      Regenerate a paper table/figure (table2 fig6 fig7a-c fig8a-c,
      ablate-{grouping,staleness,relay}) into DIR (default: results/).
      --jobs N runs surrogate sweep cells on N worker threads; output
      is bit-identical to --jobs 1 (PJRT sweeps stay sequential).

  asyncfleo run [--config FILE] [--scheme S] [--placement P]
                [--model mlp|cnn] [--dataset digits|cifar]
                [--partition iid|non-iid] [--horizon-hours H]
                [--max-epochs N] [--seed N] [--surrogate]
                [--fault-scenario nominal|lossy|eclipse|churn|hap-failure
                                  |jitter|congestion|partition|sun-eclipse]
                [--fault-intensity X]
      Run a single FL experiment and print its curve. Scenario presets
      set both the fault knobs and the network impairment engine
      (latency jitter, per-link queueing, partitions, Sun-vector
      eclipses).

  asyncfleo resilience [--out DIR] [--fast] [--surrogate] [--seed N] [--jobs N]
                       [--scenarios NAME[,NAME...]]
      Sweep the fault + network-impairment scenarios (lossy, eclipse,
      churn, hap-failure, jitter, congestion, partition, sun-eclipse)
      across AsyncFLEO + baselines and tabulate graceful degradation
      (alias for `exp resilience`). --scenarios restricts the sweep to
      the named subset (the nominal reference cell always runs).

  asyncfleo scenario --list
  asyncfleo scenario --dump NAME
  asyncfleo scenario [--preset NAME[,NAME...] | --all | --config FILE]
                     [--out DIR] [--fast] [--jobs N] [--seed N] [--pjrt]
      Declarative experiment worlds. The built-in catalog ships >= 7
      presets (paper-40, starlink-lite two-shell, polar-star, sparse-iot,
      equatorial-dense, haps-degraded, starlink-phase1 mega-scale);
      --list shows them, --dump prints
      a preset as TOML (editable, reloadable via --config FILE, with
      [shellN] sections for multi-shell constellations and [isl] /
      [isl_linkN] sections for the ISL topology graph). Running a
      selection sweeps AsyncFLEO vs FedHAP vs FedSat vs SinkSat (the
      sink-satellite scheme routed over the ISL graph) in each world
      into DIR/scenarios.csv. Surrogate backend by default (contact-pattern
      studies; --pjrt opts into the compiled artifacts); output is
      byte-identical at any --jobs N.

  asyncfleo trace [--preset NAME] [--scheme S] [--seed N] [--out FILE]
                  [--lanes N]
      Run one scenario preset (default paper-40) under one scheme
      (default: the preset's) with the typed event trace enabled and
      write the JSONL record stream to FILE (default
      results/trace.jsonl) plus a metrics/phase report.json next to
      it. Surrogate backend. --lanes N runs the multi-lane event core
      (default 1); traces are byte-identical at any lane count.
      Observation is observe-only: the traced run is bit-identical to
      an untraced one, and the trace itself is deterministic
      (tests/obs_equivalence.rs pins both).

  asyncfleo report [TRACE.jsonl]
      Summarize a trace written by `asyncfleo trace`: record counts,
      the staleness-at-aggregation histogram, the top links by
      utilization, the time-in-phase table (wall-clock, from the
      sibling report.json) and the accuracy curve.

  asyncfleo info
      Show artifact manifest + paper constellation info.

The scenario sweep also takes --report: attach metrics-only
observation to every cell and fold the per-run reports into
DIR/report.json (scenarios.csv stays byte-identical).
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // --list/--all/--pjrt are scenario-only: other subcommands must
    // keep rejecting them instead of silently swallowing a flag
    let scenario_mode = argv.first().map(|s| s == "scenario").unwrap_or(false);
    let known_flags: &[&str] = if scenario_mode {
        &["fast", "surrogate", "help", "list", "all", "pjrt", "report"]
    } else {
        &["fast", "surrogate", "help", "report"]
    };
    let args = match Args::parse(&argv, true, known_flags) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return;
    }
    let result = match args.subcommand.as_deref().unwrap() {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "resilience" => cmd_resilience(&args),
        "scenario" => cmd_scenario(&args),
        "trace" => cmd_trace(&args),
        "report" => cmd_report(&args),
        "info" => print_info(&asyncfleo::runtime::Runtime::default_dir()),
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn sweep_options(args: &Args) -> anyhow::Result<ExpOptions> {
    Ok(ExpOptions {
        out_dir: args.opt_or("out", "results").into(),
        fast: args.flag("fast"),
        surrogate: args.flag("surrogate"),
        seed: args.opt_parse::<u64>("seed").map_err(anyhow::Error::msg)?.unwrap_or(42),
        jobs: args.opt_parse::<usize>("jobs").map_err(anyhow::Error::msg)?.unwrap_or(1),
        report: args.flag("report"),
    })
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let name = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    run_experiment(name, &sweep_options(args)?)
}

fn cmd_resilience(args: &Args) -> anyhow::Result<()> {
    if let Some(names) = args.opt("scenarios") {
        let filter = names
            .split(',')
            .map(|n| {
                asyncfleo::faults::FaultScenario::parse(n.trim())
                    .ok_or_else(|| anyhow::anyhow!("unknown fault scenario {:?}", n.trim()))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        return asyncfleo::experiments::resilience::run_filtered(
            &sweep_options(args)?,
            Some(&filter),
        );
    }
    run_experiment("resilience", &sweep_options(args)?)
}

fn cmd_scenario(args: &Args) -> anyhow::Result<()> {
    let registry = ScenarioRegistry::builtin();
    if args.flag("list") {
        println!("built-in scenario catalog ({} presets):\n", registry.len());
        for sc in registry.iter() {
            println!("  {}", sc.describe());
        }
        println!("\nrun one with `asyncfleo scenario --preset NAME`, dump with `--dump NAME`");
        return Ok(());
    }
    if let Some(name) = args.opt("dump") {
        let sc = registry
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {name:?}; try --list"))?;
        print!("{}", sc.to_toml());
        return Ok(());
    }
    let mut scenarios: Vec<Scenario> = if let Some(path) = args.opt("config") {
        vec![Scenario::from_file(path).map_err(anyhow::Error::msg)?]
    } else if args.flag("all") {
        registry.iter().cloned().collect()
    } else if let Some(names) = args.opt("preset") {
        names
            .split(',')
            .map(|n| {
                registry
                    .get(n.trim())
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("unknown preset {:?}; try --list", n.trim()))
            })
            .collect::<anyhow::Result<_>>()?
    } else {
        anyhow::bail!(
            "scenario: pass --list, --dump NAME, --preset NAME[,NAME...], --all, or --config FILE"
        );
    };
    // an explicit --seed overrides every selected world's seed; without
    // it each scenario keeps the seed its definition carries
    if let Some(seed) = args.opt_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        for sc in &mut scenarios {
            sc.cfg.seed = seed;
        }
    }
    // scenario sweeps are contact-pattern studies: surrogate by default
    // (also what lets --jobs parallelize); --pjrt opts into artifacts
    let mut opts = sweep_options(args)?;
    opts.surrogate = !args.flag("pjrt");
    asyncfleo::experiments::scenarios::run_compare(&scenarios, &opts)
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let registry = ScenarioRegistry::builtin();
    let preset = args.opt_or("preset", "paper-40");
    let sc = registry
        .get(preset)
        .ok_or_else(|| anyhow::anyhow!("unknown preset {preset:?}; try `scenario --list`"))?;
    let mut cfg = sc.cfg.clone();
    if let Some(s) = args.opt("scheme") {
        cfg.fl.scheme =
            SchemeKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scheme {s}"))?;
    }
    if let Some(n) = args.opt_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        cfg.seed = n;
    }
    if let Some(h) = args.opt_parse::<f64>("horizon-hours").map_err(anyhow::Error::msg)? {
        cfg.fl.horizon_s = h * 3600.0;
    }
    let lanes = args.opt_parse::<usize>("lanes").map_err(anyhow::Error::msg)?.unwrap_or(1);
    let out = std::path::PathBuf::from(args.opt_or("out", "results/trace.jsonl"));

    let mut obs = asyncfleo::obs::RunObs::to_file(&out)?;
    obs.meta(
        preset,
        cfg.fl.scheme.name(),
        cfg.seed,
        cfg.fl.horizon_s,
        cfg.n_sats(),
        cfg.placement.sites().len(),
    );

    let mut backend = asyncfleo::train::SurrogateBackend::for_config(&cfg);
    let mut env = asyncfleo::coordinator::SimEnv::new(&cfg, &mut backend);
    env.set_lanes(lanes);
    env.enable_obs(obs);
    // contact windows are precomputed geometry: emit the open/close
    // record stream up front, ordered by open time (then site, sat)
    let geo = env.geo.clone();
    let mut contacts: Vec<(f64, f64, usize, usize)> = Vec::new();
    for site in 0..geo.plan.n_sites() {
        for sat in 0..geo.plan.n_sats() {
            for w in geo.plan.windows(site, sat) {
                contacts.push((w.start_s, w.end_s, site, sat));
            }
        }
    }
    contacts.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.2.cmp(&y.2)).then(x.3.cmp(&y.3)));
    if let Some(o) = env.obs() {
        for &(start, end, site, sat) in &contacts {
            o.contact_open(start, site, sat);
            o.contact_close(end, site, sat);
        }
    }

    println!(
        "tracing {} on {} (seed {}, {:.1} h) -> {}",
        cfg.fl.scheme.name(),
        preset,
        cfg.seed,
        cfg.fl.horizon_s / 3600.0,
        out.display()
    );
    let r = make_strategy(cfg.fl.scheme).run(&mut env);
    let mut obs = env.take_obs().expect("trace run is observed");
    obs.sink.flush();
    // fold the process-wide substrate phases (geometry build, contact
    // scan, pass-map memoization) into this run's report — wall-clock
    // timings live only here, never in the deterministic trace
    for (name, secs, _count) in asyncfleo::obs::global_phases() {
        obs.phases.add(name, secs);
    }
    let report_path = out.with_file_name("report.json");
    std::fs::write(&report_path, obs.report().to_json("") + "\n")?;
    println!(
        "done: {} epochs, final accuracy {:.2}%, {} transfers",
        r.epochs,
        r.final_accuracy * 100.0,
        r.transfers
    );
    println!("wrote {} and {}", out.display(), report_path.display());
    println!("render with `asyncfleo report {}`", out.display());
    Ok(())
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let path = std::path::PathBuf::from(
        args.opt("trace")
            .or_else(|| args.positional.first().map(String::as_str))
            .unwrap_or("results/trace.jsonl"),
    );
    let trace = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("cannot read trace {}: {e}", path.display()))?;
    let report_json = std::fs::read_to_string(path.with_file_name("report.json")).ok();
    print!("{}", asyncfleo::obs::summarize_trace(&trace, report_json.as_deref()));
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.opt("config") {
        Some(path) => ExperimentConfig::from_file(path).map_err(anyhow::Error::msg)?,
        None => ExperimentConfig::paper_defaults(),
    };
    if let Some(s) = args.opt("scheme") {
        cfg.fl.scheme =
            SchemeKind::parse(s).ok_or_else(|| anyhow::anyhow!("unknown scheme {s}"))?;
    }
    if let Some(p) = args.opt("placement") {
        cfg.placement =
            PsPlacement::parse(p).ok_or_else(|| anyhow::anyhow!("unknown placement {p}"))?;
    }
    if let Some(m) = args.opt("model") {
        cfg.fl.model = ModelKind::parse(m).ok_or_else(|| anyhow::anyhow!("unknown model {m}"))?;
    }
    if let Some(d) = args.opt("dataset") {
        cfg.fl.dataset = match d {
            "digits" | "mnist" => asyncfleo::data::DatasetKind::Digits,
            "cifar" | "cifar10" => asyncfleo::data::DatasetKind::Cifar,
            _ => anyhow::bail!("unknown dataset {d}"),
        };
    }
    if let Some(p) = args.opt("partition") {
        cfg.fl.partition = match p {
            "iid" => asyncfleo::data::Partition::Iid,
            "non-iid" | "noniid" => asyncfleo::data::Partition::NonIidPaper,
            _ => anyhow::bail!("unknown partition {p}"),
        };
    }
    if let Some(h) = args.opt_parse::<f64>("horizon-hours").map_err(anyhow::Error::msg)? {
        cfg.fl.horizon_s = h * 3600.0;
    }
    if let Some(n) = args.opt_parse::<u64>("max-epochs").map_err(anyhow::Error::msg)? {
        cfg.fl.max_epochs = n;
    }
    if let Some(n) = args.opt_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        cfg.seed = n;
    }
    if let Some(sc) = args.opt("fault-scenario") {
        let scenario = asyncfleo::faults::FaultScenario::parse(sc)
            .ok_or_else(|| anyhow::anyhow!("unknown fault scenario {sc}"))?;
        let intensity = args
            .opt_parse::<f64>("fault-intensity")
            .map_err(anyhow::Error::msg)?
            .unwrap_or(1.0);
        cfg.faults = asyncfleo::faults::FaultConfig::preset(scenario, intensity);
        cfg.network = asyncfleo::faults::NetworkConfig::preset(scenario, intensity);
    } else if args.opt("fault-intensity").is_some() {
        anyhow::bail!("--fault-intensity requires --fault-scenario");
    }
    let errs = cfg.validate();
    if !errs.is_empty() {
        anyhow::bail!("invalid config: {}", errs.join("; "));
    }

    let opts = ExpOptions { surrogate: args.flag("surrogate"), ..Default::default() };
    println!(
        "running {} @ {} ({}, {}, {})",
        cfg.fl.scheme.name(),
        cfg.placement.name(),
        cfg.model_tag(),
        if cfg.fl.partition == asyncfleo::data::Partition::Iid { "iid" } else { "non-iid" },
        if opts.surrogate { "surrogate" } else { "pjrt" },
    );
    let r = run_one(&cfg, &opts)?;
    if r.curve.points.len() >= 2 {
        println!("\n{}", asyncfleo::metrics::chart::render_curve(&r.curve, 64, 14));
    }
    println!("\n  time(h:mm)  epoch  accuracy    loss");
    for p in &r.curve.points {
        println!(
            "  {:>9}  {:>5}  {:>8.4}  {:>7.4}",
            fmt_hm(p.time_s),
            p.epoch,
            p.accuracy,
            p.loss
        );
    }
    match r.converged {
        Some((t, acc)) => println!(
            "\nconverged at {} with plateau accuracy {:.2}% ({} epochs, {} transfers)",
            fmt_hm(t),
            acc * 100.0,
            r.epochs,
            r.transfers
        ),
        None => println!(
            "\ndid not converge within horizon (final accuracy {:.2}%)",
            r.final_accuracy * 100.0
        ),
    }
    let fs = r.fault_stats;
    if fs != asyncfleo::faults::FaultStats::default() {
        println!(
            "faults: {} retransmissions over {} lossy transfers, {} deferrals \
             ({:.2} h deferred, {} at outages), {} results lost, {} churn deaths",
            fs.retransmits,
            fs.losses,
            fs.deferrals,
            fs.deferred_s / 3600.0,
            fs.outages_hit,
            fs.dropped_results,
            fs.churn_deaths
        );
        let impaired = fs.queued_s > 0.0
            || fs.queue_drops + fs.partition_hits + fs.reorders + fs.eclipse_blocked > 0
            || fs.retry_drops > 0;
        if impaired {
            println!(
                "network: {:.1} s queued ({} queue drops), {} partition hits, \
                 {} reorders, {} eclipse-blocked passes, {} retry-budget drops",
                fs.queued_s,
                fs.queue_drops,
                fs.partition_hits,
                fs.reorders,
                fs.eclipse_blocked,
                fs.retry_drops
            );
        }
    }
    Ok(())
}
