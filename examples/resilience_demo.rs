//! Resilience demo: AsyncFLEO vs a synchronous baseline under faults.
//!
//! Runs the same two-HAP constellation through increasingly hostile
//! fault scenarios (packet loss, eclipse outages, satellite churn, HAP
//! failure) on the fast surrogate backend, and prints a degradation
//! table: how much accuracy and convergence speed each scheme loses as
//! the network stops being perfect. The asynchronous design's point is
//! visible directly — synchronous rounds stall behind dead satellites,
//! AsyncFLEO keeps aggregating whatever arrives.
//!
//! ```bash
//! cargo run --release --example resilience_demo
//! ```

use asyncfleo::config::{ExperimentConfig, ModelKind, PsPlacement, SchemeKind};
use asyncfleo::coordinator::SimEnv;
use asyncfleo::data::{DatasetKind, Partition};
use asyncfleo::faults::{FaultConfig, FaultScenario};
use asyncfleo::fl::make_strategy;
use asyncfleo::train::SurrogateBackend;
use asyncfleo::util::fmt_hm;

fn main() -> anyhow::Result<()> {
    let mut cfg0 = ExperimentConfig::paper_defaults();
    cfg0.fl.model = ModelKind::Mlp;
    cfg0.fl.dataset = DatasetKind::Digits;
    cfg0.fl.partition = Partition::NonIidPaper;
    cfg0.placement = PsPlacement::TwoHaps;
    cfg0.fl.horizon_s = 48.0 * 3600.0;
    cfg0.fl.max_epochs = 30;

    let schemes = [SchemeKind::AsyncFleo, SchemeKind::FedHap];
    let scenarios = [
        (FaultScenario::Nominal, 0.0),
        (FaultScenario::Lossy, 1.0),
        (FaultScenario::Eclipse, 1.0),
        (FaultScenario::Churn, 1.0),
        (FaultScenario::HapFailure, 1.0),
    ];

    println!(
        "{:<12} {:<10} {:>8} {:>11} {:>7} {:>9} {:>9} {:>8}",
        "scenario", "scheme", "acc(%)", "conv(h:mm)", "epochs", "transfers", "retrans", "dropped"
    );
    for (scenario, intensity) in scenarios {
        for scheme in schemes {
            let mut cfg = cfg0.clone();
            cfg.fl.scheme = scheme;
            cfg.faults = FaultConfig::preset(scenario, intensity);

            let mut backend = SurrogateBackend::paper_split(
                cfg.constellation.n_orbits,
                cfg.constellation.sats_per_orbit,
                false,
                100,
            );
            let mut env = SimEnv::new(&cfg, &mut backend);
            let r = make_strategy(scheme).run(&mut env);

            let (conv_t, acc) = match r.converged {
                Some((t, a)) => (t, a),
                None => (
                    r.curve.points.last().map(|p| p.time_s).unwrap_or(0.0),
                    r.final_accuracy,
                ),
            };
            println!(
                "{:<12} {:<10} {:>8.2} {:>11} {:>7} {:>9} {:>9} {:>8}",
                scenario.name(),
                scheme.name(),
                acc * 100.0,
                fmt_hm(conv_t),
                r.epochs,
                r.transfers,
                r.fault_stats.retransmits,
                r.fault_stats.dropped_results
            );
        }
    }
    println!(
        "\nSame seed → same impairment timeline for every scheme; rerun to see\n\
         bit-identical numbers. Sweep intensities with `asyncfleo resilience`."
    );
    Ok(())
}
