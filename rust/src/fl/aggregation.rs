//! Model selection + staleness-discounted aggregation coefficients
//! (paper Sec. IV-C2, Eqs. 13–14).
//!
//! Per group G_i: if *any* member model is fresh (its `epoch` equals
//! the current β), only the fresh members are selected and the stale
//! ones discarded *for this epoch*; if a group has only stale models
//! they are all selected but discounted.
//!
//! The paper's Eq. 13 defines the discount mass
//! γ = Σ_n (D_n/D)(k_n/β) over the selected models, where **D is the
//! total data size of *all* satellites** (not just the selected ones),
//! and Eq. 14 mixes `(1-γ)·w^β + Σ γ_n·w_n` with per-model
//! γ_n = (D_n/D)·(k_n/β) so that Σγ_n = γ and the update is a convex
//! combination. Two consequences the paper's rationale leans on:
//! * **partial participation is anchored** — if only a quarter of the
//!   constellation's data is represented this epoch, γ ≈ 0.25 and the
//!   previous global model keeps most of its weight (without this the
//!   global model oscillates with whatever subset arrives first);
//! * **staleness discounts** — a model trained against epoch k_n < β
//!   enters with its share scaled by k_n/β, and only when its whole
//!   group is stale (fresh models are preferred by selection).
//! When every satellite is selected and fresh, γ = 1 and the update
//! reduces to plain data-size-weighted FedAvg (Eq. 4).

use crate::model::ModelMetadata;

/// One candidate model at the sink: its metadata + its group id.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub meta: ModelMetadata,
    pub group: usize,
}

/// The outcome of selection: which candidates participate (by index
/// into the candidate slice) and with what coefficient; plus the
/// coefficient of the previous global model.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    pub chosen: Vec<(usize, f32)>,
    pub coeff_prev: f32,
    /// γ of Eq. 13 (= Σ of chosen coefficients).
    pub gamma: f32,
}

impl Default for Selection {
    /// The empty selection: nothing chosen, the previous global model
    /// keeps full weight.
    fn default() -> Self {
        Selection { chosen: Vec::new(), coeff_prev: 1.0, gamma: 0.0 }
    }
}

/// Apply the group-wise fresh/stale selection rule (Sec. IV-C2).
/// Returns indices into `candidates` that participate this epoch.
pub fn select_models(candidates: &[Candidate], current_epoch: u64) -> Vec<usize> {
    let mut scratch = SelectionScratch::default();
    select_models_into(candidates, current_epoch, &mut scratch);
    std::mem::take(&mut scratch.selected)
}

/// Reusable buffers for the per-epoch selection (one allocation set
/// per run — the sink calls selection on every aggregation).
#[derive(Clone, Debug, Default)]
pub struct SelectionScratch {
    /// Selected candidate indices, group-major (the selection output).
    pub selected: Vec<usize>,
    /// Per-group "has a fresh member" table.
    fresh: Vec<bool>,
}

/// In-place [`select_models`]: fills `scratch.selected`. The selection
/// order (group-major, ascending candidate index within each group) is
/// identical to the allocating path — downstream coefficient sums fold
/// in the same order, so every float is unchanged.
pub fn select_models_into(
    candidates: &[Candidate],
    current_epoch: u64,
    scratch: &mut SelectionScratch,
) {
    let n_groups = candidates.iter().map(|c| c.group).max().map_or(0, |g| g + 1);
    scratch.selected.clear();
    scratch.fresh.clear();
    scratch.fresh.resize(n_groups, false);
    for c in candidates {
        if c.meta.is_fresh(current_epoch) {
            scratch.fresh[c.group] = true;
        }
    }
    for g in 0..n_groups {
        for (i, c) in candidates.iter().enumerate() {
            if c.group == g && (!scratch.fresh[g] || c.meta.is_fresh(current_epoch)) {
                scratch.selected.push(i);
            }
        }
    }
}

/// Compute the aggregation coefficients (Eqs. 13–14) for the selected
/// candidates. `total_data` is D of Eq. 13: the total data size of the
/// whole constellation (pass the sum over *all* satellites; 0 falls
/// back to the selected sum, losing the partial-participation anchor).
pub fn staleness_coefficients(
    candidates: &[Candidate],
    selected: &[usize],
    current_epoch: u64,
    total_data: usize,
) -> Selection {
    let mut out = Selection::default();
    staleness_coefficients_into(candidates, selected, current_epoch, total_data, &mut out);
    out
}

/// In-place [`staleness_coefficients`]: reuses `out.chosen`'s
/// allocation. Identical accumulation order ⇒ identical floats.
pub fn staleness_coefficients_into(
    candidates: &[Candidate],
    selected: &[usize],
    current_epoch: u64,
    total_data: usize,
    out: &mut Selection,
) {
    out.chosen.clear();
    if selected.is_empty() {
        out.coeff_prev = 1.0;
        out.gamma = 0.0;
        return;
    }
    let selected_sum: f64 =
        selected.iter().map(|&i| candidates[i].meta.data_size as f64).sum();
    let d_total = if total_data > 0 { total_data as f64 } else { selected_sum };
    let mut gamma = 0.0f64;
    for &i in selected {
        let m = &candidates[i].meta;
        let share = if d_total > 0.0 { m.data_size as f64 / d_total } else { 0.0 };
        let g_n = share * m.staleness_ratio(current_epoch);
        gamma += g_n;
        out.chosen.push((i, g_n as f32));
    }
    let gamma = gamma.clamp(0.0, 1.0);
    out.coeff_prev = (1.0 - gamma) as f32;
    out.gamma = gamma as f32;
}

/// Convenience: full selection + coefficients in one call.
pub fn select_and_weigh(
    candidates: &[Candidate],
    current_epoch: u64,
    total_data: usize,
) -> Selection {
    let mut scratch = SelectionScratch::default();
    let mut out = Selection::default();
    select_and_weigh_into(candidates, current_epoch, total_data, &mut scratch, &mut out);
    out
}

/// In-place [`select_and_weigh`]: the allocation-free epoch path the
/// sink loop runs (scratch + `out` reused across aggregations).
pub fn select_and_weigh_into(
    candidates: &[Candidate],
    current_epoch: u64,
    total_data: usize,
    scratch: &mut SelectionScratch,
    out: &mut Selection,
) {
    select_models_into(candidates, current_epoch, scratch);
    staleness_coefficients_into(candidates, &scratch.selected, current_epoch, total_data, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(sat: usize, group: usize, epoch: u64, size: usize) -> Candidate {
        Candidate {
            meta: ModelMetadata {
                sat_id: sat,
                orbit: group,
                data_size: size,
                loc_rad: 0.0,
                ts_s: 0.0,
                epoch,
            },
            group,
        }
    }

    #[test]
    fn all_fresh_is_fedavg() {
        let cs = vec![cand(0, 0, 5, 100), cand(1, 0, 5, 300), cand(2, 1, 5, 100)];
        // whole constellation participating: D = sum of shard sizes
        let sel = select_and_weigh(&cs, 5, 500);
        assert_eq!(sel.chosen.len(), 3);
        assert!((sel.gamma - 1.0).abs() < 1e-6);
        assert!(sel.coeff_prev.abs() < 1e-6);
        // weights proportional to data size
        let w: Vec<f32> = sel.chosen.iter().map(|&(_, w)| w).collect();
        assert!((w[0] - 0.2).abs() < 1e-6);
        assert!((w[1] - 0.6).abs() < 1e-6);
        assert!((w[2] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn stale_discarded_when_group_has_fresh() {
        let cs = vec![cand(0, 0, 5, 100), cand(1, 0, 3, 100)];
        let selected = select_models(&cs, 5);
        assert_eq!(selected, vec![0]);
    }

    #[test]
    fn all_stale_group_kept_with_discount() {
        let cs = vec![cand(0, 0, 2, 100), cand(1, 0, 3, 100)];
        let sel = select_and_weigh(&cs, 4, 200);
        assert_eq!(sel.chosen.len(), 2);
        // gamma = 0.5*(2/4) + 0.5*(3/4) = 0.625
        assert!((sel.gamma - 0.625).abs() < 1e-6);
        assert!((sel.coeff_prev - 0.375).abs() < 1e-6);
    }

    #[test]
    fn mixed_groups_independent() {
        // group 0 has a fresh model; group 1 only stale
        let cs = vec![cand(0, 0, 6, 100), cand(1, 0, 2, 100), cand(2, 1, 3, 100)];
        let selected = select_models(&cs, 6);
        assert_eq!(selected, vec![0, 2]);
        let sel = staleness_coefficients(&cs, &selected, 6, 200);
        // fresh share 0.5*1.0 + stale share 0.5*(3/6) = 0.75
        assert!((sel.gamma - 0.75).abs() < 1e-6);
    }

    #[test]
    fn partial_participation_anchors_previous_global() {
        // Eq. 13's D is the WHOLE constellation's data: with only a
        // quarter of the data represented, gamma ~ 0.25 and the
        // previous global model keeps ~0.75 weight.
        let cs = vec![cand(0, 0, 5, 100), cand(1, 1, 5, 150)];
        let sel = select_and_weigh(&cs, 5, 1000);
        assert!((sel.gamma - 0.25).abs() < 1e-6, "gamma {}", sel.gamma);
        assert!((sel.coeff_prev - 0.75).abs() < 1e-6);
    }

    #[test]
    fn empty_candidates() {
        let sel = select_and_weigh(&[], 3, 1000);
        assert!(sel.chosen.is_empty());
        assert_eq!(sel.coeff_prev, 1.0);
    }

    #[test]
    fn coefficients_form_convex_combination() {
        crate::testkit::forall(|rng| {
            let n = rng.range_usize(1, 20);
            let beta = rng.range_usize(1, 10) as u64;
            let cs: Vec<Candidate> = (0..n)
                .map(|i| {
                    cand(
                        i,
                        rng.below(4),
                        rng.below(beta as usize + 1) as u64,
                        rng.range_usize(10, 500),
                    )
                })
                .collect();
            // D >= sum of candidate sizes (non-participants exist too)
            let total_data: usize = cs.iter().map(|c| c.meta.data_size).sum::<usize>()
                + rng.range_usize(0, 5000);
            let sel = select_and_weigh(&cs, beta, total_data);
            let total: f32 =
                sel.coeff_prev + sel.chosen.iter().map(|&(_, w)| w).sum::<f32>();
            assert!((total - 1.0).abs() < 1e-4, "total {total}");
            for &(_, w) in &sel.chosen {
                assert!((0.0..=1.0).contains(&w));
            }
        });
    }

    #[test]
    fn epoch_zero_counts_as_fresh() {
        let cs = vec![cand(0, 0, 0, 100)];
        let sel = select_and_weigh(&cs, 0, 100);
        assert!((sel.gamma - 1.0).abs() < 1e-6);
    }

    #[test]
    fn in_place_selection_matches_allocating_bitwise() {
        crate::testkit::forall(|rng| {
            let n = rng.range_usize(0, 16);
            let beta = rng.range_usize(1, 8) as u64;
            let cs: Vec<Candidate> = (0..n)
                .map(|i| {
                    cand(
                        i,
                        rng.below(4),
                        rng.below(beta as usize + 1) as u64,
                        rng.range_usize(10, 500),
                    )
                })
                .collect();
            let total = rng.range_usize(0, 4000);
            let want = select_and_weigh(&cs, beta, total);
            // dirty, reused scratch/out across cases — the run-loop shape
            let mut scratch = SelectionScratch::default();
            scratch.selected.push(999);
            let mut got = Selection { chosen: vec![(7, 0.5)], coeff_prev: 0.0, gamma: 0.9 };
            select_and_weigh_into(&cs, beta, total, &mut scratch, &mut got);
            assert_eq!(want.chosen.len(), got.chosen.len());
            for (&(i, w), &(j, v)) in want.chosen.iter().zip(&got.chosen) {
                assert_eq!(i, j);
                assert_eq!(w.to_bits(), v.to_bits());
            }
            assert_eq!(want.coeff_prev.to_bits(), got.coeff_prev.to_bits());
            assert_eq!(want.gamma.to_bits(), got.gamma.to_bits());
        });
    }
}
