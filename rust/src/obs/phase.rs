//! Phase profiling: scoped wall-time timers whose totals surface in
//! `report.json` and `BENCH_runloop.json` — never in the trace JSONL
//! (wall-clock readings would break the trace's byte-determinism).
//!
//! Two registries:
//!
//! * [`PhaseTimes`] — the per-run accumulator carried by
//!   `obs::RunObs`. Strategies bracket their event processing and
//!   aggregation with `SimEnv::phase_start` / `SimEnv::phase_end`,
//!   which cost one `Option` branch when observation is off.
//! * the process-wide global registry ([`global_phase`] /
//!   [`global_phases`]) — for cold-path substrate phases that run
//!   inside process-wide caches with no run to charge them to:
//!   geometry build, the contact scan, analytic pass-map
//!   memoization. A [`ScopedPhase`] guard adds its elapsed time on
//!   drop; these sites build each unique artifact once per process,
//!   so the mutex is far off every hot path.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Per-run accumulated wall time by phase name.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    acc: BTreeMap<&'static str, (f64, u64)>,
}

impl PhaseTimes {
    /// Charge `secs` of wall time to `name`.
    pub fn add(&mut self, name: &'static str, secs: f64) {
        let e = self.acc.entry(name).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    /// `(name, total seconds, times entered)` in name order.
    pub fn entries(&self) -> impl Iterator<Item = (&'static str, f64, u64)> + '_ {
        self.acc.iter().map(|(&n, &(s, c))| (n, s, c))
    }

    pub fn get(&self, name: &str) -> Option<(f64, u64)> {
        self.acc.get(name).copied()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }
}

fn global() -> &'static Mutex<BTreeMap<&'static str, (f64, u64)>> {
    static REG: OnceLock<Mutex<BTreeMap<&'static str, (f64, u64)>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Guard returned by [`global_phase`]: charges its elapsed wall time
/// to the global registry when dropped.
pub struct ScopedPhase {
    name: &'static str,
    t0: Instant,
}

/// Start timing a named substrate phase (geometry build, contact scan,
/// pass-map memoization). Hold the guard for the phase's extent.
pub fn global_phase(name: &'static str) -> ScopedPhase {
    ScopedPhase { name, t0: Instant::now() }
}

impl Drop for ScopedPhase {
    fn drop(&mut self) {
        let secs = self.t0.elapsed().as_secs_f64();
        let mut reg = global().lock().unwrap();
        let e = reg.entry(self.name).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }
}

/// Snapshot of the process-wide substrate phases:
/// `(name, total seconds, times entered)` in name order.
pub fn global_phases() -> Vec<(&'static str, f64, u64)> {
    global()
        .lock()
        .unwrap()
        .iter()
        .map(|(&n, &(s, c))| (n, s, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_accumulate() {
        let mut p = PhaseTimes::default();
        assert!(p.is_empty());
        p.add("aggregate", 0.25);
        p.add("aggregate", 0.75);
        p.add("event_loop", 2.0);
        assert_eq!(p.get("aggregate"), Some((1.0, 2)));
        let rows: Vec<_> = p.entries().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "aggregate", "BTreeMap order is deterministic");
    }

    #[test]
    fn scoped_phase_lands_in_global_registry() {
        {
            let _g = global_phase("obs_phase_unit_test");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let rows = global_phases();
        let row = rows
            .iter()
            .find(|(n, _, _)| *n == "obs_phase_unit_test")
            .expect("guard must register its phase");
        assert!(row.1 > 0.0);
        assert!(row.2 >= 1);
    }
}
