//! FL data partitioning across the constellation (paper Sec. V-A).
//!
//! * **IID** — samples shuffled and spread evenly: every satellite holds
//!   all 10 classes.
//! * **Non-IID (the paper's split)** — satellites of two orbits hold 4
//!   classes, satellites of the other three orbits hold the remaining
//!   6 classes. Because orbits sweep different geographic bands this is
//!   the natural non-IID structure for Satcom.
//!
//! Shard sizes vary mildly (±25%) to exercise the data-size weighting
//! in Eq. (12)–(13).

use super::synth::Dataset;
use crate::util::Rng;

/// How data is spread over satellites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Partition {
    Iid,
    /// The paper's orbit-wise label split (2 orbits: classes 0..4,
    /// 3 orbits: classes 4..10).
    NonIidPaper,
}

/// One satellite's shard: indices into the shared [`Dataset`].
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub indices: Vec<usize>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
}

/// Split `data` into `n_orbits * sats_per_orbit` shards.
pub fn partition(
    data: &Dataset,
    scheme: Partition,
    n_orbits: usize,
    sats_per_orbit: usize,
    seed: u64,
) -> Vec<Shard> {
    let n_sats = n_orbits * sats_per_orbit;
    let mut rng = Rng::new(seed ^ 0x5A4D);
    match scheme {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..data.len()).collect();
            rng.shuffle(&mut idx);
            deal_with_jitter(&idx, n_sats, &mut rng)
        }
        Partition::NonIidPaper => {
            // Orbits 0..2 -> classes 0..4; orbits 2..n -> classes 4..10.
            let k = data.kind.classes() as u8;
            let split = 4u8.min(k);
            let mut low: Vec<usize> = (0..data.len()).filter(|&i| data.y[i] < split).collect();
            let mut high: Vec<usize> = (0..data.len()).filter(|&i| data.y[i] >= split).collect();
            rng.shuffle(&mut low);
            rng.shuffle(&mut high);
            let low_orbits = 2.min(n_orbits);
            let low_sats = low_orbits * sats_per_orbit;
            let high_sats = n_sats - low_sats;
            let mut shards = deal_with_jitter(&low, low_sats.max(1), &mut rng);
            if high_sats > 0 {
                shards.extend(deal_with_jitter(&high, high_sats, &mut rng));
            }
            shards.truncate(n_sats);
            while shards.len() < n_sats {
                shards.push(Shard::default());
            }
            shards
        }
    }
}

/// Deal indices across `n` shards with ±25% size jitter.
fn deal_with_jitter(idx: &[usize], n: usize, rng: &mut Rng) -> Vec<Shard> {
    assert!(n > 0);
    // draw relative weights in [0.75, 1.25], normalize to partition.
    let weights: Vec<f64> = (0..n).map(|_| rng.range_f64(0.75, 1.25)).collect();
    let total: f64 = weights.iter().sum();
    let mut shards = Vec::with_capacity(n);
    let mut cursor = 0usize;
    for (i, w) in weights.iter().enumerate() {
        let take = if i + 1 == n {
            idx.len() - cursor
        } else {
            ((w / total) * idx.len() as f64).round() as usize
        };
        let take = take.min(idx.len() - cursor);
        shards.push(Shard { indices: idx[cursor..cursor + take].to_vec() });
        cursor += take;
    }
    shards
}

/// Distinct classes present in a shard.
pub fn shard_classes(data: &Dataset, shard: &Shard) -> Vec<u8> {
    let mut seen = [false; 256];
    for &i in &shard.indices {
        seen[data.y[i] as usize] = true;
    }
    (0..=255u8).filter(|&c| seen[c as usize]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, DatasetKind};

    fn data() -> Dataset {
        generate(DatasetKind::Digits, 0, 4000)
    }

    #[test]
    fn iid_partition_covers_all_disjointly() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 1);
        assert_eq!(shards.len(), 40);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..4000).collect::<Vec<_>>());
    }

    #[test]
    fn iid_shards_have_most_classes() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 1);
        for s in &shards {
            assert!(shard_classes(&d, s).len() >= 8, "IID shard missing classes");
        }
    }

    #[test]
    fn non_iid_respects_orbit_class_split() {
        let d = data();
        let shards = partition(&d, Partition::NonIidPaper, 5, 8, 1);
        assert_eq!(shards.len(), 40);
        // first two orbits (sats 0..16): only classes 0..4
        for s in &shards[..16] {
            for c in shard_classes(&d, s) {
                assert!(c < 4, "low orbit has class {c}");
            }
        }
        // remaining orbits: only classes 4..10
        for s in &shards[16..] {
            for c in shard_classes(&d, s) {
                assert!((4..10).contains(&c), "high orbit has class {c}");
            }
        }
    }

    #[test]
    fn non_iid_covers_all_disjointly() {
        let d = data();
        let shards = partition(&d, Partition::NonIidPaper, 5, 8, 1);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..4000).collect::<Vec<_>>());
    }

    #[test]
    fn shard_sizes_vary_but_bounded() {
        let d = data();
        let shards = partition(&d, Partition::Iid, 5, 8, 2);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(min > 0);
        assert!(max as f64 / min as f64 <= 2.0, "sizes {min}..{max}");
        assert!(max != min, "jitter should vary sizes");
    }

    #[test]
    fn deterministic_in_seed() {
        let d = data();
        let a = partition(&d, Partition::NonIidPaper, 5, 8, 3);
        let b = partition(&d, Partition::NonIidPaper, 5, 8, 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn small_constellations_work() {
        let d = generate(DatasetKind::Digits, 1, 300);
        let shards = partition(&d, Partition::NonIidPaper, 3, 2, 0);
        assert_eq!(shards.len(), 6);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 300);
    }
}
