//! The explicit ISL graph (paper Sec. IV-A generalized; follow-up
//! paper arXiv 2302.13447): satellites are nodes, inter-satellite
//! links are **typed edges** with per-shell RF budgets and per-edge
//! delays derived from the actual geometry at query time.
//!
//! Three edge kinds ([`IslEdgeKind`]):
//!
//! * **intra-plane ring** — the two adjacent slots of the same orbital
//!   plane ([`WalkerConstellation::ring_neighbors`]); the only kind the
//!   paper permits (inter-orbit Doppler, Sec. IV-A). A graph built with
//!   [`IslTopology::Ring`] contains exactly these edges — the
//!   executable reference the topology tests pin against
//!   `ring_neighbors`, so every pre-graph scheme keeps its exact
//!   semantics;
//! * **cross-plane grid** — slot *i* of plane *p* to slot *i* of plane
//!   *p+1* within the same shell ([`IslTopology::Grid`], the classic
//!   +Grid pattern);
//! * **cross-shell** — one gateway edge per plane of the lower shell to
//!   the closest (at epoch, deterministic tie-break) gateway satellite
//!   of the next shell up, so stacked shells can exchange models
//!   without descending to the parameter server.
//!
//! Every edge carries the [`LinkParams`] of its shell (cross-shell
//! edges use the lower shell's budget), so a 550 km shell and a
//! 1200 km shell no longer share one RF budget. Per-edge delay is the
//! crate-wide composition (transmission + propagation + processing)
//! with the transmission rate **Doppler-derated**: the carrier offset
//! [`crate::orbit::sat_sat_doppler_hz`] shrinks the usable bandwidth
//! (`B_eff = max(B − 2|Δf|, B/10)`), which leaves intra-plane rings
//! untouched (|Δf| ≈ 0 — the paper's design rule, quantified in
//! [`crate::orbit::doppler`]) and penalizes cross-plane / cross-shell
//! edges in proportion to their relative velocity.
//!
//! Routing ([`IslGraph::shortest_delays`] / [`IslGraph::route`]) is
//! Dijkstra over a snapshot of edge delays at the query instant, with
//! a deterministic tie-break (equal-delay frontier entries pop in
//! node-id order and never displace an established parent), so routes
//! are reproducible across runs and thread counts.

use crate::comm::LinkParams;
use crate::orbit::{sat_sat_doppler_hz, WalkerConstellation};
use crate::util::SPEED_OF_LIGHT_KM_S;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Which edge set the graph is built with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IslTopology {
    /// Intra-plane rings only (the paper's topology; the reference).
    Ring,
    /// Rings plus same-slot cross-plane edges within each shell.
    Grid,
}

impl IslTopology {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ring" => Some(IslTopology::Ring),
            "grid" => Some(IslTopology::Grid),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            IslTopology::Ring => "ring",
            IslTopology::Grid => "grid",
        }
    }
}

/// ISL graph configuration (the `[isl]` scenario TOML section plus the
/// optional `[isl_linkN]` per-shell link sections).
#[derive(Clone, Debug, PartialEq)]
pub struct IslConfig {
    /// Edge set: `ring` (paper default) or `grid`.
    pub topology: IslTopology,
    /// Add gateway edges between adjacent shells.
    pub cross_shell: bool,
    /// Doppler-derate per-edge transmission rates.
    pub doppler: bool,
    /// Per-shell link-budget overrides, index = shell. Shells beyond
    /// the list fall back to the experiment's global `LinkParams`.
    pub shell_links: Vec<LinkParams>,
}

impl Default for IslConfig {
    fn default() -> Self {
        IslConfig {
            topology: IslTopology::Ring,
            cross_shell: false,
            doppler: true,
            shell_links: Vec::new(),
        }
    }
}

/// Bit pattern of one `LinkParams` (for cache keys).
pub fn link_key_bits(l: &LinkParams) -> [u64; 8] {
    [
        l.tx_power_dbm.to_bits(),
        l.tx_gain_dbi.to_bits(),
        l.rx_gain_dbi.to_bits(),
        l.carrier_hz.to_bits(),
        l.noise_temp_k.to_bits(),
        l.bandwidth_hz.to_bits(),
        l.data_rate_bps.to_bits(),
        l.processing_delay_s.to_bits(),
    ]
}

impl IslConfig {
    /// The link budget governing edges of `shell`.
    pub fn shell_link(&self, shell: usize, default: &LinkParams) -> LinkParams {
        self.shell_links.get(shell).copied().unwrap_or(*default)
    }

    /// Exact bit pattern of every graph-relevant knob — the `[isl]`
    /// contribution to the geometry cache key.
    pub fn key_bits(&self) -> Vec<u64> {
        let mut v = vec![
            match self.topology {
                IslTopology::Ring => 0,
                IslTopology::Grid => 1,
            },
            u64::from(self.cross_shell),
            u64::from(self.doppler),
            self.shell_links.len() as u64,
        ];
        for l in &self.shell_links {
            v.extend_from_slice(&link_key_bits(l));
        }
        v
    }
}

/// The type of an ISL edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IslEdgeKind {
    IntraPlane,
    CrossPlane,
    CrossShell,
}

/// One undirected ISL edge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IslEdge {
    pub a: u32,
    pub b: u32,
    pub kind: IslEdgeKind,
    /// The shell whose [`LinkParams`] govern this edge (for
    /// cross-shell edges: the lower of the two shells).
    pub shell: u32,
}

/// Dijkstra output: per-node delay from the source and the parent
/// pointer tree (source's parent is `usize::MAX`).
#[derive(Clone, Debug)]
pub struct RoutePlan {
    pub source: usize,
    pub dist: Vec<f64>,
    pub parent: Vec<usize>,
}

impl RoutePlan {
    /// The node path source→`to` (inclusive), or `None` if unreachable.
    pub fn path_to(&self, to: usize) -> Option<Vec<usize>> {
        if !self.dist[to].is_finite() {
            return None;
        }
        let mut path = vec![to];
        let mut cur = to;
        while cur != self.source {
            cur = self.parent[cur];
            path.push(cur);
        }
        path.reverse();
        Some(path)
    }

    /// Hop count source→`to`, or `None` if unreachable.
    pub fn hops_to(&self, to: usize) -> Option<usize> {
        if !self.dist[to].is_finite() {
            return None;
        }
        let mut hops = 0;
        let mut cur = to;
        while cur != self.source {
            cur = self.parent[cur];
            hops += 1;
        }
        Some(hops)
    }
}

/// Min-heap entry ordered by (delay, node id) — the deterministic
/// tie-break of the router.
struct Frontier(f64, usize);

impl PartialEq for Frontier {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}
impl Eq for Frontier {}
impl PartialOrd for Frontier {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Frontier {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0).then(self.1.cmp(&other.1))
    }
}

/// The explicit ISL graph of a constellation.
#[derive(Clone, Debug)]
pub struct IslGraph {
    n: usize,
    doppler: bool,
    edges: Vec<IslEdge>,
    /// Per node: `(edge index, neighbor id)`, sorted by neighbor id.
    adj: Vec<Vec<(u32, u32)>>,
    /// Intra-plane ring tables, one entry per node: previous / next
    /// slot in the same plane and the node's ring position. Filled for
    /// every topology (the intra-plane ring is part of every edge set),
    /// so ring-routed schemes can read their neighborhood off the graph
    /// without consulting general adjacency — which would leak grid /
    /// gateway edges into schemes defined on the ring.
    ring_prev: Vec<u32>,
    ring_next: Vec<u32>,
    ring_pos: Vec<u32>,
    /// Resolved per-shell link budgets (index = shell).
    links: Vec<LinkParams>,
}

impl IslGraph {
    /// Build the edge set for `c` under `cfg`. Deterministic: edges are
    /// emitted shell by shell, plane by plane, slot by slot, and the
    /// cross-shell gateway choice breaks distance ties by satellite id.
    pub fn build(c: &WalkerConstellation, cfg: &IslConfig, default_link: &LinkParams) -> Self {
        let n = c.len();
        let links: Vec<LinkParams> =
            (0..c.n_shells()).map(|s| cfg.shell_link(s, default_link)).collect();
        let mut edges: Vec<IslEdge> = Vec::new();
        let mut push = |a: usize, b: usize, kind: IslEdgeKind, shell: usize| {
            edges.push(IslEdge { a: a as u32, b: b as u32, kind, shell: shell as u32 });
        };

        // intra-plane rings (every topology) + per-node ring tables
        // (identical to `WalkerConstellation::ring_neighbors` / slot by
        // construction; a single-member plane points at itself)
        let mut ring_prev: Vec<u32> = (0..n as u32).collect();
        let mut ring_next: Vec<u32> = (0..n as u32).collect();
        let mut ring_pos: Vec<u32> = vec![0; n];
        for orbit in 0..c.n_orbits {
            let members = c.orbit_members(orbit);
            let (start, len) = (members.start, members.len());
            let shell = c.satellites[start].shell;
            for i in 0..len {
                ring_pos[start + i] = i as u32;
                ring_prev[start + i] = (start + (i + len - 1) % len) as u32;
                ring_next[start + i] = (start + (i + 1) % len) as u32;
            }
            if len == 2 {
                push(start, start + 1, IslEdgeKind::IntraPlane, shell);
            } else if len >= 3 {
                for i in 0..len {
                    push(start + i, start + (i + 1) % len, IslEdgeKind::IntraPlane, shell);
                }
            }
        }

        // cross-plane grid edges, per shell
        if cfg.topology == IslTopology::Grid {
            let mut plane0 = 0usize; // first global plane index of the shell
            for (shell, sh) in c.shells.iter().enumerate() {
                for q in 0..sh.n_orbits {
                    // q -> q+1; the wrap edge only when it is not a
                    // duplicate of the forward edge (needs >= 3 planes)
                    if q + 1 >= sh.n_orbits && sh.n_orbits < 3 {
                        continue;
                    }
                    let pa = c.orbit_members(plane0 + q);
                    let pb = c.orbit_members(plane0 + (q + 1) % sh.n_orbits);
                    for i in 0..sh.sats_per_orbit {
                        push(pa.start + i, pb.start + i, IslEdgeKind::CrossPlane, shell);
                    }
                }
                plane0 += sh.n_orbits;
            }
        }

        // cross-shell gateways: one edge per plane of the lower shell
        if cfg.cross_shell && c.n_shells() >= 2 {
            let mut plane0 = 0usize;
            for shell in 0..c.n_shells() - 1 {
                let upper = c.shell_id_range(shell + 1);
                // candidate gateways above: slot 0 of each upper plane
                let candidates: Vec<usize> =
                    upper.clone().filter(|&id| c.satellites[id].slot == 0).collect();
                for q in 0..c.shells[shell].n_orbits {
                    let gw = c.orbit_members(plane0 + q).start; // slot 0
                    let p_gw = c.position(gw, 0.0);
                    let mut best: Option<(f64, usize)> = None;
                    for &cand in &candidates {
                        let d = (c.position(cand, 0.0) - p_gw).norm();
                        let better = match best {
                            None => true,
                            Some((bd, bid)) => {
                                d.total_cmp(&bd).then(cand.cmp(&bid)).is_lt()
                            }
                        };
                        if better {
                            best = Some((d, cand));
                        }
                    }
                    if let Some((_, cand)) = best {
                        push(gw, cand, IslEdgeKind::CrossShell, shell);
                    }
                }
                plane0 += c.shells[shell].n_orbits;
            }
        }

        let mut adj: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n];
        for (e, edge) in edges.iter().enumerate() {
            adj[edge.a as usize].push((e as u32, edge.b));
            adj[edge.b as usize].push((e as u32, edge.a));
        }
        for list in &mut adj {
            list.sort_unstable_by_key(|&(_, nb)| nb);
        }
        IslGraph { n, doppler: cfg.doppler, edges, adj, ring_prev, ring_next, ring_pos, links }
    }

    /// Intra-plane ring neighbors of `id` as `(prev, next)` — the same
    /// integers as [`WalkerConstellation::ring_neighbors`] (pinned by
    /// tests). Available under every topology, so ring-routed schemes
    /// (`fl::propagation`) read the ring off the graph without their
    /// semantics depending on the configured edge set.
    pub fn ring_neighbors(&self, id: usize) -> (usize, usize) {
        (self.ring_prev[id] as usize, self.ring_next[id] as usize)
    }

    /// In-plane ring position (slot index) of `id`.
    pub fn ring_pos(&self, id: usize) -> usize {
        self.ring_pos[id] as usize
    }

    pub fn n_nodes(&self) -> usize {
        self.n
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[IslEdge] {
        &self.edges
    }

    /// Number of edges of one kind.
    pub fn count_kind(&self, kind: IslEdgeKind) -> usize {
        self.edges.iter().filter(|e| e.kind == kind).count()
    }

    /// Neighbor ids of `id`, ascending.
    pub fn neighbors(&self, id: usize) -> impl Iterator<Item = usize> + '_ {
        self.adj[id].iter().map(|&(_, nb)| nb as usize)
    }

    /// The edge index joining adjacent nodes `a` and `b`, if any
    /// (direction-agnostic; the adjacency rows are sorted by neighbor).
    pub fn edge_between(&self, a: usize, b: usize) -> Option<usize> {
        let row = self.adj.get(a)?;
        let i = row.binary_search_by_key(&(b as u32), |&(_, nb)| nb).ok()?;
        Some(row[i].0 as usize)
    }

    /// The link budget governing edge `e`.
    pub fn edge_link(&self, e: usize) -> &LinkParams {
        &self.links[self.edges[e].shell as usize]
    }

    /// Doppler rate-derate factor of edge `e` at time `t`: the carrier
    /// offset shrinks the usable bandwidth, `B_eff/B ∈ [0.1, 1]`.
    /// Symmetric in the endpoints (|Δf| is) and ≈ 1 on intra-plane
    /// rings.
    pub fn doppler_factor(&self, c: &WalkerConstellation, e: usize, t: f64) -> f64 {
        if !self.doppler {
            return 1.0;
        }
        let edge = &self.edges[e];
        let p = &self.links[edge.shell as usize];
        let df = sat_sat_doppler_hz(c, edge.a as usize, edge.b as usize, t, p.carrier_hz).abs();
        (p.bandwidth_hz - 2.0 * df).max(0.1 * p.bandwidth_hz) / p.bandwidth_hz
    }

    /// One-hop delay over edge `e` at time `t` for a payload of
    /// `payload_bits`: transmission at the Doppler-derated shell rate,
    /// plus propagation at the instantaneous range, plus processing.
    pub fn edge_delay_s(
        &self,
        c: &WalkerConstellation,
        e: usize,
        t: f64,
        payload_bits: f64,
    ) -> f64 {
        let edge = &self.edges[e];
        let p = &self.links[edge.shell as usize];
        let d_km = (c.position(edge.a as usize, t) - c.position(edge.b as usize, t)).norm();
        let rate = p.data_rate_bps * self.doppler_factor(c, e, t);
        payload_bits / rate + d_km / SPEED_OF_LIGHT_KM_S + p.processing_delay_s
    }

    /// Shortest-delay tree from `from`: Dijkstra over a snapshot of
    /// every edge delay at instant `t`. Deterministic tie-break: the
    /// frontier orders by (delay, node id) and relaxation is
    /// strictly-less, so an equal-delay alternative never displaces an
    /// established parent.
    pub fn shortest_delays(
        &self,
        c: &WalkerConstellation,
        from: usize,
        t: f64,
        payload_bits: f64,
    ) -> RoutePlan {
        let w: Vec<f64> =
            (0..self.edges.len()).map(|e| self.edge_delay_s(c, e, t, payload_bits)).collect();
        let mut dist = vec![f64::INFINITY; self.n];
        let mut parent = vec![usize::MAX; self.n];
        let mut done = vec![false; self.n];
        let mut heap: BinaryHeap<Reverse<Frontier>> = BinaryHeap::new();
        dist[from] = 0.0;
        heap.push(Reverse(Frontier(0.0, from)));
        while let Some(Reverse(Frontier(_, u))) = heap.pop() {
            if done[u] {
                continue;
            }
            done[u] = true;
            for &(e, v) in &self.adj[u] {
                let v = v as usize;
                let nd = dist[u] + w[e as usize];
                if nd < dist[v] {
                    dist[v] = nd;
                    parent[v] = u;
                    heap.push(Reverse(Frontier(nd, v)));
                }
            }
        }
        RoutePlan { source: from, dist, parent }
    }

    /// Shortest-delay route `from`→`to` at instant `t`:
    /// `(total delay, node path)` or `None` if disconnected.
    pub fn route(
        &self,
        c: &WalkerConstellation,
        from: usize,
        to: usize,
        t: f64,
        payload_bits: f64,
    ) -> Option<(f64, Vec<usize>)> {
        let plan = self.shortest_delays(c, from, t, payload_bits);
        plan.path_to(to).map(|path| (plan.dist[to], path))
    }

    /// Is the graph a single connected component?
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &(_, v) in &self.adj[u] {
                let v = v as usize;
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::ShellSpec;

    const BITS: f64 = 1_000_000.0;

    fn paper() -> WalkerConstellation {
        WalkerConstellation::paper()
    }

    fn ring_graph(c: &WalkerConstellation) -> IslGraph {
        IslGraph::build(c, &IslConfig::default(), &LinkParams::default())
    }

    fn grid_graph(c: &WalkerConstellation) -> IslGraph {
        let cfg = IslConfig { topology: IslTopology::Grid, cross_shell: true, ..Default::default() };
        IslGraph::build(c, &cfg, &LinkParams::default())
    }

    #[test]
    fn ring_graph_matches_ring_neighbors() {
        // The Ring graph is the executable reference: its neighbor sets
        // are exactly `ring_neighbors` on every satellite.
        let c = paper();
        let g = ring_graph(&c);
        assert_eq!(g.count_kind(IslEdgeKind::CrossPlane), 0);
        assert_eq!(g.count_kind(IslEdgeKind::CrossShell), 0);
        for id in 0..c.len() {
            let (prev, next) = c.ring_neighbors(id);
            let mut expect = vec![prev, next];
            expect.sort_unstable();
            expect.dedup();
            let got: Vec<usize> = g.neighbors(id).collect();
            assert_eq!(got, expect, "sat {id}");
        }
    }

    #[test]
    fn ring_tables_pin_ring_neighbors_and_slots_under_every_topology() {
        // The per-node ring tables must reproduce the constellation's
        // `ring_neighbors` / `slot` integers exactly — including on
        // multi-shell worlds with odd plane sizes and under the Grid
        // topology (the tables must not depend on the edge set).
        let multi = WalkerConstellation::from_shells(&[
            ShellSpec::delta(2, 3, 551.5, 53.0, 1),
            ShellSpec::delta(3, 4, 1111.5, 53.8, 1),
            ShellSpec::delta(1, 2, 1475.5, 70.0, 0),
        ]);
        for c in [&paper(), &multi] {
            for g in [&ring_graph(c), &grid_graph(c)] {
                for id in 0..c.len() {
                    assert_eq!(g.ring_neighbors(id), c.ring_neighbors(id), "sat {id}");
                    assert_eq!(g.ring_pos(id), c.satellites[id].slot, "sat {id}");
                }
            }
        }
    }

    #[test]
    fn grid_graph_is_connected_ring_is_not() {
        let c = paper();
        assert!(!ring_graph(&c).is_connected(), "5 disjoint plane rings");
        let g = grid_graph(&c);
        assert!(g.is_connected());
        assert_eq!(g.count_kind(IslEdgeKind::IntraPlane), 40);
        assert_eq!(g.count_kind(IslEdgeKind::CrossPlane), 40, "5 planes x 8 slots");
    }

    #[test]
    fn cross_shell_gateways_connect_stacked_shells() {
        let c = WalkerConstellation::from_shells(&[
            ShellSpec::delta(2, 4, 550.0, 53.0, 1),
            ShellSpec::delta(3, 4, 1110.0, 53.8, 1),
        ]);
        let no_gw = IslGraph::build(
            &c,
            &IslConfig { topology: IslTopology::Grid, ..Default::default() },
            &LinkParams::default(),
        );
        assert!(!no_gw.is_connected(), "shells only meet through gateways");
        let g = grid_graph(&c);
        assert!(g.is_connected());
        assert_eq!(g.count_kind(IslEdgeKind::CrossShell), 2, "one per lower-shell plane");
        for e in g.edges().iter().filter(|e| e.kind == IslEdgeKind::CrossShell) {
            assert_eq!(c.satellites[e.a as usize].shell, 0);
            assert_eq!(c.satellites[e.b as usize].shell, 1);
            assert_eq!(e.shell, 0, "lower shell's budget governs");
        }
    }

    #[test]
    fn edge_delays_finite_symmetric_and_doppler_bounded() {
        let c = paper();
        let g = grid_graph(&c);
        for t in [0.0, 1800.0, 7200.0] {
            for e in 0..g.n_edges() {
                let d = g.edge_delay_s(&c, e, t, BITS);
                assert!(d.is_finite() && d > 0.0, "edge {e} delay {d}");
                let f = g.doppler_factor(&c, e, t);
                assert!((0.1..=1.0).contains(&f), "edge {e} factor {f}");
            }
        }
        // symmetry: |Δf| and range are endpoint-symmetric, so a graph
        // built with every edge flipped yields identical delays
        let mut flipped = g.clone();
        for e in &mut flipped.edges {
            std::mem::swap(&mut e.a, &mut e.b);
        }
        for e in 0..g.n_edges() {
            assert_eq!(
                g.edge_delay_s(&c, e, 900.0, BITS).to_bits(),
                flipped.edge_delay_s(&c, e, 900.0, BITS).to_bits(),
                "edge {e}"
            );
        }
    }

    #[test]
    fn intra_plane_rate_is_doppler_clean_cross_plane_is_derated() {
        let c = paper();
        let g = grid_graph(&c);
        let intra = g
            .edges()
            .iter()
            .position(|e| e.kind == IslEdgeKind::IntraPlane)
            .unwrap();
        let cross = g
            .edges()
            .iter()
            .position(|e| e.kind == IslEdgeKind::CrossPlane)
            .unwrap();
        let fi = g.doppler_factor(&c, intra, 600.0);
        let fc = g.doppler_factor(&c, cross, 600.0);
        assert!(fi > 0.99999, "intra-plane ≈ no derate, got {fi}");
        assert!(fc < fi, "cross-plane derated below intra-plane: {fc} vs {fi}");
    }

    #[test]
    fn per_shell_link_budget_is_used() {
        let c = WalkerConstellation::from_shells(&[
            ShellSpec::delta(2, 4, 550.0, 53.0, 1),
            ShellSpec::delta(2, 4, 1110.0, 53.8, 1),
        ]);
        let slow = LinkParams { data_rate_bps: 1.0e6, ..LinkParams::default() };
        let cfg = IslConfig {
            shell_links: vec![LinkParams::default(), slow],
            doppler: false,
            ..Default::default()
        };
        let g = IslGraph::build(&c, &cfg, &LinkParams::default());
        let e0 = g.edges().iter().position(|e| e.shell == 0).unwrap();
        let e1 = g.edges().iter().position(|e| e.shell == 1).unwrap();
        assert_eq!(g.edge_link(e1).data_rate_bps, 1.0e6);
        // same payload: the slow shell's transmission dominates
        let d0 = g.edge_delay_s(&c, e0, 0.0, BITS);
        let d1 = g.edge_delay_s(&c, e1, 0.0, BITS);
        assert!(d1 > d0, "slow shell {d1} vs default shell {d0}");
    }

    #[test]
    fn routes_are_shortest_and_deterministic() {
        let c = paper();
        let g = grid_graph(&c);
        let (delay, path) = g.route(&c, 0, 20, 0.0, BITS).expect("connected");
        assert!(delay.is_finite() && delay > 0.0);
        assert_eq!(path.first(), Some(&0));
        assert_eq!(path.last(), Some(&20));
        // consecutive path nodes are graph neighbors
        for w in path.windows(2) {
            assert!(g.neighbors(w[0]).any(|nb| nb == w[1]), "{w:?}");
        }
        // deterministic: identical plan on a repeat query
        let p1 = g.shortest_delays(&c, 3, 1234.0, BITS);
        let p2 = g.shortest_delays(&c, 3, 1234.0, BITS);
        assert_eq!(p1.parent, p2.parent);
        for (a, b) in p1.dist.iter().zip(&p2.dist) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // one-hop optimality: a direct neighbor's distance is its edge
        // delay (no shorter multi-hop detour exists at these scales)
        let plan = g.shortest_delays(&c, 0, 0.0, BITS);
        for &(e, nb) in &g.adj[0] {
            assert!(plan.dist[nb as usize] <= g.edge_delay_s(&c, e as usize, 0.0, BITS) + 1e-12);
        }
    }

    #[test]
    fn key_bits_distinguish_configs() {
        let base = IslConfig::default();
        let grid = IslConfig { topology: IslTopology::Grid, ..base.clone() };
        let linked = IslConfig {
            shell_links: vec![LinkParams { data_rate_bps: 1.0e6, ..LinkParams::default() }],
            ..base.clone()
        };
        assert_ne!(base.key_bits(), grid.key_bits());
        assert_ne!(base.key_bits(), linked.key_bits());
        assert_eq!(base.key_bits(), IslConfig::default().key_bits());
    }
}
